//! Readiness polling for the event-loop gateway: a thin, std-only
//! abstraction over raw `epoll(7)` plus an `eventfd(2)` waker, declared
//! through direct `extern "C"` bindings (std already links libc on
//! Linux; the vendored crate set has no `libc` crate).
//!
//! The surface is deliberately tiny — register/modify/deregister a fd
//! under a `u64` token with read/write [`Interest`], block in
//! [`Poller::wait`] for [`Event`]s, and cross-thread-wake the loop via
//! [`Waker`]. Level-triggered semantics throughout: an fd keeps
//! reporting ready until the condition is consumed, so the loop never
//! needs to drain a socket to exhaustion inside one event.

use std::io;
use std::time::Duration;

/// What a registration wants to hear about. Hangup/error conditions
/// are always reported regardless of interest, so a connection parked
/// on an in-flight job (`Interest::NONE`) still learns about peer
/// disconnects without busy-waking on readable bytes it refuses to
/// consume.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    pub read: bool,
    pub write: bool,
}

impl Interest {
    pub const NONE: Interest = Interest { read: false, write: false };
    pub const READ: Interest = Interest { read: true, write: false };
    pub const WRITE: Interest = Interest { read: false, write: true };
    pub const BOTH: Interest = Interest { read: true, write: true };
}

/// One readiness notification out of [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token the fd was registered under.
    pub token: u64,
    /// Reading will make progress (data, EOF, or a pending error).
    pub readable: bool,
    /// Writing will make progress (or surface a pending error).
    pub writable: bool,
    /// The peer closed or the socket errored (`EPOLLRDHUP`/`HUP`/`ERR`).
    pub hangup: bool,
}

#[cfg(target_os = "linux")]
mod sys {
    use super::{Event, Interest};
    use std::io;
    use std::os::fd::RawFd;
    use std::os::raw::{c_int, c_uint, c_void};
    use std::time::Duration;

    const EPOLL_CLOEXEC: c_int = 0o2000000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;
    const EFD_NONBLOCK: c_int = 0o4000;
    const EFD_CLOEXEC: c_int = 0o2000000;
    const RLIMIT_NOFILE: c_int = 7;

    /// `struct epoll_event` — packed on x86-64 (the kernel ABI quirk),
    /// naturally aligned elsewhere. Fields are only ever read by value.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    #[repr(C)]
    struct Rlimit {
        cur: u64,
        max: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            max_events: c_int,
            timeout_ms: c_int,
        ) -> c_int;
        fn eventfd(initval: c_uint, flags: c_int) -> c_int;
        fn close(fd: c_int) -> c_int;
        fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
        fn getrlimit(resource: c_int, rlim: *mut Rlimit) -> c_int;
        fn setrlimit(resource: c_int, rlim: *const Rlimit) -> c_int;
    }

    fn mask(interest: Interest) -> u32 {
        let mut m = EPOLLRDHUP;
        if interest.read {
            m |= EPOLLIN;
        }
        if interest.write {
            m |= EPOLLOUT;
        }
        m
    }

    /// Level-triggered epoll instance plus a reusable kernel-facing
    /// event buffer (sized by `max_events` at construction — the knob
    /// `GatewayConfig::max_events` feeds).
    pub struct Poller {
        epfd: RawFd,
        scratch: Vec<EpollEvent>,
    }

    impl Poller {
        pub fn new(max_events: usize) -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller {
                epfd,
                scratch: Vec::with_capacity(max_events.clamp(1, 4096)),
            })
        }

        fn ctl(&self, op: c_int, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            let mut ev = EpollEvent {
                events: mask(interest),
                data: token,
            };
            let arg = if op == EPOLL_CTL_DEL {
                std::ptr::null_mut()
            } else {
                &mut ev as *mut EpollEvent
            };
            if unsafe { epoll_ctl(self.epfd, op, fd, arg) } < 0 {
                Err(io::Error::last_os_error())
            } else {
                Ok(())
            }
        }

        pub fn register(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, Interest::NONE)
        }

        /// Block until readiness or `timeout` (`None` = forever),
        /// appending decoded events into `out` (cleared first). An
        /// `EINTR` wakeup returns an empty set rather than an error.
        pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            out.clear();
            let timeout_ms: c_int = match timeout {
                None => -1,
                Some(d) if d.is_zero() => 0,
                // round sub-millisecond timeouts up so a 100µs tick
                // cannot degenerate into a busy spin
                Some(d) => d.as_millis().clamp(1, c_int::MAX as u128) as c_int,
            };
            let n = unsafe {
                epoll_wait(
                    self.epfd,
                    self.scratch.as_mut_ptr(),
                    self.scratch.capacity() as c_int,
                    timeout_ms,
                )
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(err);
            }
            // The kernel filled the first `n` slots of the scratch
            // buffer; adopt them (plain-old-data, no Drop).
            unsafe { self.scratch.set_len(n as usize) };
            for ev in &self.scratch {
                let (events, data) = (ev.events, ev.data);
                out.push(Event {
                    token: data,
                    readable: events & (EPOLLIN | EPOLLRDHUP | EPOLLHUP | EPOLLERR) != 0,
                    writable: events & (EPOLLOUT | EPOLLHUP | EPOLLERR) != 0,
                    hangup: events & (EPOLLRDHUP | EPOLLHUP | EPOLLERR) != 0,
                });
            }
            self.scratch.clear();
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe { close(self.epfd) };
        }
    }

    /// Cross-thread wakeup for a parked [`Poller::wait`]: an eventfd
    /// registered read-interest under a reserved token. Completion
    /// pumps call [`Waker::wake`]; the loop calls [`Waker::drain`]
    /// when it sees the token, then collects completions.
    pub struct Waker {
        fd: RawFd,
    }

    impl Waker {
        pub fn new(poller: &Poller, token: u64) -> io::Result<Waker> {
            let fd = unsafe { eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC) };
            if fd < 0 {
                return Err(io::Error::last_os_error());
            }
            let w = Waker { fd };
            poller.register(fd, token, Interest::READ)?;
            Ok(w)
        }

        /// Nudge the loop. Infallible by design: if the 64-bit counter
        /// is saturated the fd is already readable and the wakeup is
        /// already pending.
        pub fn wake(&self) {
            let one: u64 = 1;
            unsafe { write(self.fd, &one as *const u64 as *const c_void, 8) };
        }

        /// Consume pending wakeups so level-triggered polling settles.
        pub fn drain(&self) {
            let mut counter: u64 = 0;
            // one read zeroes the eventfd counter; loop only to be
            // robust against a concurrent wake between read and return
            for _ in 0..2 {
                let n = unsafe { read(self.fd, &mut counter as *mut u64 as *mut c_void, 8) };
                if n <= 0 {
                    break;
                }
            }
        }
    }

    impl Drop for Waker {
        fn drop(&mut self) {
            unsafe { close(self.fd) };
        }
    }

    // Safety: the waker is a bare fd; write(2) on an eventfd is
    // thread-safe.
    unsafe impl Send for Waker {}
    unsafe impl Sync for Waker {}

    /// Lift the soft `RLIMIT_NOFILE` toward `target` (capped at the
    /// hard limit) so C10K-scale benches and probes can actually open
    /// their sockets. Returns the resulting soft limit.
    pub fn raise_nofile_limit(target: u64) -> io::Result<u64> {
        let mut lim = Rlimit { cur: 0, max: 0 };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
            return Err(io::Error::last_os_error());
        }
        if lim.cur >= target {
            return Ok(lim.cur);
        }
        let want = target.min(lim.max);
        let new = Rlimit {
            cur: want,
            max: lim.max,
        };
        if unsafe { setrlimit(RLIMIT_NOFILE, &new) } != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(want)
    }
}

#[cfg(target_os = "linux")]
pub use sys::{raise_nofile_limit, Poller, Waker};

#[cfg(not(target_os = "linux"))]
compile_error!(
    "net::poll backs the gateway event loop with raw epoll; \
     port Poller/Waker to kqueue or poll(2) for this platform"
);

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;
    use std::time::Duration;

    #[test]
    fn waker_wakes_and_drains() {
        let mut poller = Poller::new(8).unwrap();
        let waker = Waker::new(&poller, 1).unwrap();
        let mut events = Vec::new();

        // nothing pending: a short wait times out empty
        poller
            .wait(&mut events, Some(Duration::from_millis(5)))
            .unwrap();
        assert!(events.is_empty());

        waker.wake();
        waker.wake(); // coalesces
        poller
            .wait(&mut events, Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 1);
        assert!(events[0].readable);

        waker.drain();
        poller
            .wait(&mut events, Some(Duration::from_millis(5)))
            .unwrap();
        assert!(events.is_empty(), "drained waker must go quiet");
    }

    #[test]
    fn listener_and_stream_readiness() {
        let mut poller = Poller::new(8).unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        poller
            .register(listener.as_raw_fd(), 7, Interest::READ)
            .unwrap();

        let mut events = Vec::new();
        let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        poller
            .wait(&mut events, Some(Duration::from_secs(5)))
            .unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable));

        let (accepted, _) = listener.accept().unwrap();
        accepted.set_nonblocking(true).unwrap();

        // a fresh socket with write interest is immediately writable
        poller
            .register(accepted.as_raw_fd(), 9, Interest::BOTH)
            .unwrap();
        poller
            .wait(&mut events, Some(Duration::from_secs(5)))
            .unwrap();
        assert!(events.iter().any(|e| e.token == 9 && e.writable));

        // parked interest (NONE) still reports peer hangup
        poller
            .modify(accepted.as_raw_fd(), 9, Interest::NONE)
            .unwrap();
        drop(client);
        poller
            .wait(&mut events, Some(Duration::from_secs(5)))
            .unwrap();
        assert!(
            events.iter().any(|e| e.token == 9 && e.hangup),
            "expected hangup event, got {events:?}"
        );

        poller.deregister(accepted.as_raw_fd()).unwrap();
    }

    #[test]
    fn data_readiness_round_trip() {
        let mut poller = Poller::new(8).unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (accepted, _) = listener.accept().unwrap();
        accepted.set_nonblocking(true).unwrap();
        poller
            .register(accepted.as_raw_fd(), 3, Interest::READ)
            .unwrap();

        let mut events = Vec::new();
        poller
            .wait(&mut events, Some(Duration::from_millis(10)))
            .unwrap();
        assert!(
            events.iter().all(|e| !e.readable),
            "no bytes yet, got {events:?}"
        );

        client.write_all(b"ping").unwrap();
        poller
            .wait(&mut events, Some(Duration::from_secs(5)))
            .unwrap();
        assert!(events.iter().any(|e| e.token == 3 && e.readable));
    }

    #[test]
    fn nofile_limit_is_queryable_and_monotone() {
        let cur = raise_nofile_limit(0).unwrap();
        assert!(cur > 0);
        let after = raise_nofile_limit(cur).unwrap();
        assert!(after >= cur);
    }
}
