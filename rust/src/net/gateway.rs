//! The HTTP serving gateway: a `TcpListener` accept loop feeding a
//! bounded connection-worker pool, routing requests onto the
//! replicated serving tier — the subsystem that turns the in-process
//! coordinator into a network service. std-only by construction (no
//! tokio/hyper/serde in the vendored crate set, see DESIGN.md
//! §Environment).
//!
//! Architecture (one process):
//!
//! ```text
//! clients ──TCP──▶ accept loop ──bounded queue──▶ N conn workers
//!                                                   │  (HTTP/1.1,
//!                                                   │   keep-alive)
//!                    ┌──────────────────────────────┘
//!                    ▼ submit (admission-bounded)
//!   classify leader: Server::serve_replicated  ─┐ replies
//!   generate leader: Server::serve_generate    ─┤ chunks   ──▶ routers
//!                    (long-lived, channel-fed)  ┘      (id → waiting
//!                                                       conn worker)
//! ```
//!
//! * `POST /v1/classify` — batched classification through
//!   `serve_replicated`'s admission + continuous-batching path.
//! * `POST /v1/generate` — `Transfer-Encoding: chunked` streaming of
//!   [`GenChunk`] tokens as they leave the decode batcher.
//! * `GET /metrics` — Prometheus text: the live tier snapshot rendered
//!   through the same [`MetricRow`]s the CLI `Display` impls print
//!   (one source of truth), plus gateway-level counters and per-shard
//!   plan-cache stats.
//! * `GET /healthz` — readiness (flips to `503 draining` on shutdown).
//! * `POST /admin/shutdown` — begin a graceful drain remotely.
//!
//! **Backpressure is wired to the real bound**: the classify admission
//! counter tracks submitted-but-unreplied requests against the same
//! `BatchPolicy::max_queue` the leader stops pulling at, so instead of
//! queueing unboundedly the gateway answers `429` with `Retry-After`
//! the moment the tier is saturated. Generate sessions are bounded by
//! `max_sessions` the same way.
//!
//! **Graceful shutdown** ([`ShutdownHandle`]): flag flip → `/healthz`
//! reports draining and new work gets 503 → the work channels close →
//! in-flight batches and generate streams run to completion → the
//! listener wakes (self-connect) and closes. The leaders' final
//! [`ServeOutcome`]/[`GenerateOutcome`] come back from
//! [`Gateway::join`].

use std::collections::HashMap;
use std::fmt;
use std::io::{self, ErrorKind, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{
    BatchPolicy, GenChunk, GenRequest, GenerateOutcome, MetricRow, Mode, Reply, ServeOutcome,
    Server,
};
use crate::coordinator::Request as ClassifyRequest;
use crate::decode::{DecodeConfig, Sampling};
use crate::net::http::{self, ChunkedWriter, Request, RequestParser};
use crate::net::json::{self, Json};
use crate::util::stats::LatencyWindow;

/// Gateway lifecycle states.
const RUNNING: u8 = 0;
const DRAINING: u8 = 1;
const STOPPED: u8 = 2;

/// Largest classify batch one HTTP request may carry.
pub const MAX_BATCH_PER_REQUEST: usize = 64;

/// Largest `max_new` one generate request may ask for.
pub const MAX_NEW_CAP: usize = 1024;

/// Gateway deployment knobs.
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// Bind address (`127.0.0.1:0` picks an ephemeral port).
    pub addr: String,
    /// Connection-worker pool size; accepted connections beyond it
    /// queue in a bounded handoff (then the TCP backlog).
    pub max_conns: usize,
    /// Replicas per tier (classify and generate each own a pool).
    pub replicas: usize,
    /// Classify execution mode of the backing server.
    pub mode: Mode,
    /// Leader batching policy; `max_queue` doubles as the 429 bound.
    pub policy: BatchPolicy,
    /// Decode configuration for `/v1/generate` sessions.
    pub decode: DecodeConfig,
    /// Decode steps per dispatched slice (continuous batching grain).
    pub steps_per_slice: usize,
    /// Live generate sessions admitted before 429.
    pub max_sessions: usize,
    /// Request-body cap (413 beyond it).
    pub max_body: usize,
    /// How long a connection worker waits on the tier before 500.
    pub request_timeout: Duration,
    /// Idle keep-alive connections are closed after this.
    pub keep_alive_idle: Duration,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            max_conns: 8,
            replicas: 1,
            mode: Mode::Dense,
            policy: BatchPolicy::default(),
            decode: DecodeConfig::default(),
            steps_per_slice: 4,
            max_sessions: 16,
            max_body: http::DEFAULT_MAX_BODY,
            request_timeout: Duration::from_secs(30),
            keep_alive_idle: Duration::from_secs(10),
        }
    }
}

/// Gateway-level counters (the tier-level numbers come from
/// [`Server::live_snapshot`]).
#[derive(Default)]
struct GatewayStats {
    connections_total: AtomicUsize,
    http_requests_total: AtomicUsize,
    responses_2xx: AtomicUsize,
    responses_4xx: AtomicUsize,
    responses_5xx: AtomicUsize,
    /// 429s from the admission bounds (subset of responses_4xx).
    shed_total: AtomicUsize,
    /// Requests the HTTP layer rejected before routing (parse/framing).
    bad_requests_total: AtomicUsize,
    streams_total: AtomicUsize,
    stream_tokens_total: AtomicUsize,
}

impl GatewayStats {
    fn record_status(&self, code: u16) {
        match code {
            200..=299 => &self.responses_2xx,
            400..=499 => &self.responses_4xx,
            _ => &self.responses_5xx,
        }
        .fetch_add(1, Ordering::Relaxed);
        if code == 429 {
            self.shed_total.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Work submission half of one tier: the leader's request sender, the
/// id → waiting-handler routing table, and the admission counter the
/// 429 bound checks.
struct Submitter<Req, Resp> {
    tx: Mutex<Option<mpsc::Sender<Req>>>,
    pending: Mutex<HashMap<u64, mpsc::Sender<Resp>>>,
    next_id: AtomicU64,
    in_flight: AtomicUsize,
}

impl<Req, Resp> Submitter<Req, Resp> {
    fn new(tx: mpsc::Sender<Req>) -> Self {
        Self {
            tx: Mutex::new(Some(tx)),
            pending: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(0),
            in_flight: AtomicUsize::new(0),
        }
    }

    /// Reserve `n` admission slots against `bound`; false = shed (429).
    fn try_admit(&self, n: usize, bound: usize) -> bool {
        self.in_flight
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |cur| {
                if cur + n > bound {
                    None
                } else {
                    Some(cur + n)
                }
            })
            .is_ok()
    }

    fn release(&self, n: usize) {
        self.in_flight.fetch_sub(n, Ordering::SeqCst);
    }

    fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Allocate `n` ids, all routed to one fresh reply channel.
    fn register(&self, n: usize) -> (Vec<u64>, mpsc::Receiver<Resp>) {
        let (tx, rx) = mpsc::channel();
        let mut pending = self.pending.lock().unwrap();
        let ids = (0..n)
            .map(|_| {
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                pending.insert(id, tx.clone());
                id
            })
            .collect();
        (ids, rx)
    }

    fn unregister(&self, ids: &[u64]) {
        let mut pending = self.pending.lock().unwrap();
        for id in ids {
            pending.remove(id);
        }
    }

    /// Send every request while holding the sender lock (so a racing
    /// drain can't close the channel mid-batch). False = tier gone or
    /// draining; nothing was delivered for the ids whose send failed.
    fn send_all(&self, reqs: Vec<Req>) -> bool {
        let guard = self.tx.lock().unwrap();
        match guard.as_ref() {
            Some(tx) => reqs.into_iter().all(|r| tx.send(r).is_ok()),
            None => false,
        }
    }

    /// Router side: forward one response to its waiting handler.
    fn route(&self, id: u64, resp: Resp, done: bool) {
        let mut pending = self.pending.lock().unwrap();
        if done {
            if let Some(tx) = pending.remove(&id) {
                let _ = tx.send(resp);
            }
        } else if let Some(tx) = pending.get(&id) {
            let _ = tx.send(resp);
        }
    }

    /// Drop the leader's sender: no further submissions; the leader
    /// drains what it already buffered and returns its outcome.
    fn close(&self) {
        self.tx.lock().unwrap().take();
    }
}

/// State shared by every gateway thread.
struct Inner {
    server: Arc<Server>,
    cfg: GatewayConfig,
    local_addr: SocketAddr,
    state: AtomicU8,
    stats: GatewayStats,
    classify: Submitter<ClassifyRequest, Reply>,
    generate: Submitter<GenRequest, GenChunk>,
    /// HTTP requests currently being handled (the drain barrier).
    active_requests: AtomicUsize,
    /// HTTP-level classify latencies for the /metrics gauge.
    classify_latencies: Mutex<LatencyWindow>,
    started: Instant,
}

impl Inner {
    fn state(&self) -> u8 {
        self.state.load(Ordering::SeqCst)
    }

    /// Flip to draining and close the work channels. Idempotent.
    fn begin_drain(&self) {
        if self
            .state
            .compare_exchange(RUNNING, DRAINING, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
        {
            self.classify.close();
            self.generate.close();
        }
    }

    fn record_classify_latency(&self, seconds: f64) {
        self.classify_latencies.lock().unwrap().push(seconds);
    }
}

/// Handle for triggering a graceful drain from another thread (or from
/// the `/admin/shutdown` route).
#[derive(Clone)]
pub struct ShutdownHandle {
    inner: Arc<Inner>,
}

impl ShutdownHandle {
    /// Begin draining: `/healthz` flips to 503, new work is refused,
    /// in-flight work (including open generate streams) completes,
    /// then the listener closes. Returns immediately; use
    /// [`Gateway::join`] to wait for the drain to finish.
    pub fn shutdown(&self) {
        self.inner.begin_drain();
    }
}

/// Final accounting returned by [`Gateway::join`]: the leaders' joined
/// outcomes plus gateway-level totals.
#[derive(Debug)]
pub struct GatewayReport {
    pub classify: ServeOutcome,
    pub generate: GenerateOutcome,
    pub connections: usize,
    pub http_requests: usize,
    pub shed: usize,
}

impl fmt::Display for GatewayReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "gateway_connections_total                    {}", self.connections)?;
        writeln!(f, "gateway_http_requests_total                  {}", self.http_requests)?;
        writeln!(f, "gateway_shed_total                           {}", self.shed)?;
        write!(f, "{}{}", self.classify, self.generate)
    }
}

/// The running gateway: owns the accept loop, the connection workers,
/// the two leader threads, and their routers.
pub struct Gateway {
    inner: Arc<Inner>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    routers: Vec<JoinHandle<()>>,
    drainer: Option<JoinHandle<()>>,
    classify_leader: Option<JoinHandle<Result<ServeOutcome>>>,
    generate_leader: Option<JoinHandle<Result<GenerateOutcome>>>,
}

impl Gateway {
    /// Bind, spawn the serving tier, and start accepting.
    pub fn start(server: Arc<Server>, cfg: GatewayConfig) -> Result<Gateway> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding gateway to {}", cfg.addr))?;
        let local_addr = listener.local_addr()?;

        let (creq_tx, creq_rx) = mpsc::channel::<ClassifyRequest>();
        let (crep_tx, crep_rx) = mpsc::channel::<Reply>();
        let (greq_tx, greq_rx) = mpsc::channel::<GenRequest>();
        let (gchk_tx, gchk_rx) = mpsc::channel::<GenChunk>();

        let inner = Arc::new(Inner {
            server: Arc::clone(&server),
            local_addr,
            state: AtomicU8::new(RUNNING),
            stats: GatewayStats::default(),
            classify: Submitter::new(creq_tx),
            generate: Submitter::new(greq_tx),
            active_requests: AtomicUsize::new(0),
            classify_latencies: Mutex::new(LatencyWindow::default()),
            started: Instant::now(),
            cfg,
        });
        let cfg = &inner.cfg;

        // --- leaders: long-lived serve loops fed by the channels -----
        let classify_leader = {
            let srv = Arc::clone(&server);
            let (policy, replicas) = (cfg.policy, cfg.replicas);
            std::thread::Builder::new()
                .name("esact-http-classify".to_string())
                .spawn(move || srv.serve_replicated(creq_rx, crep_tx, policy, replicas))?
        };
        let generate_leader = {
            let srv = Arc::clone(&server);
            let (decode, replicas, steps) = (cfg.decode, cfg.replicas, cfg.steps_per_slice);
            std::thread::Builder::new()
                .name("esact-http-generate".to_string())
                .spawn(move || srv.serve_generate(greq_rx, gchk_tx, decode, replicas, steps))?
        };

        // --- routers: tier responses → the waiting conn workers ------
        let classify_router = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new().name("esact-http-crouter".to_string()).spawn(
                move || {
                    for reply in crep_rx.iter() {
                        inner.classify.release(1);
                        let id = reply.id;
                        inner.classify.route(id, reply, true);
                    }
                },
            )?
        };
        let generate_router = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new().name("esact-http-grouter".to_string()).spawn(
                move || {
                    for chunk in gchk_rx.iter() {
                        let done = chunk.done;
                        if done {
                            inner.generate.release(1);
                        }
                        let id = chunk.id;
                        inner.generate.route(id, chunk, done);
                    }
                },
            )?
        };

        // --- bounded connection pool ---------------------------------
        let pool = inner.cfg.max_conns.max(1);
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(pool);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let workers = (0..pool)
            .map(|i| {
                let inner = Arc::clone(&inner);
                let conn_rx = Arc::clone(&conn_rx);
                std::thread::Builder::new()
                    .name(format!("esact-http-conn-{i}"))
                    .spawn(move || loop {
                        let stream = conn_rx.lock().unwrap().recv();
                        match stream {
                            Ok(s) => handle_conn(&inner, s),
                            Err(_) => break, // accept loop gone
                        }
                    })
                    .expect("spawn conn worker")
            })
            .collect();

        // --- accept loop ---------------------------------------------
        let accept = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new().name("esact-http-accept".to_string()).spawn(
                move || {
                    for stream in listener.incoming() {
                        if inner.state() == STOPPED {
                            break; // the drainer's poke lands here
                        }
                        let Ok(stream) = stream else { continue };
                        inner.stats.connections_total.fetch_add(1, Ordering::Relaxed);
                        // bounded handoff: all workers busy and the
                        // queue full → this blocks, pushing backpressure
                        // into the TCP backlog
                        if conn_tx.send(stream).is_err() {
                            break;
                        }
                    }
                    // listener (and conn_tx) drop here: workers drain
                    // the queued streams, then exit
                },
            )?
        };

        // --- drainer: DRAINING → (in-flight == 0) → STOPPED ----------
        let drainer = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new().name("esact-http-drain".to_string()).spawn(
                move || loop {
                    std::thread::sleep(Duration::from_millis(20));
                    match inner.state() {
                        DRAINING => {
                            let idle = inner.classify.in_flight() == 0
                                && inner.generate.in_flight() == 0
                                && inner.active_requests.load(Ordering::SeqCst) == 0;
                            if idle {
                                inner.state.store(STOPPED, Ordering::SeqCst);
                                poke_listener(inner.local_addr);
                                break;
                            }
                        }
                        RUNNING => {}
                        _ => break,
                    }
                },
            )?
        };

        Ok(Gateway {
            inner,
            accept: Some(accept),
            workers,
            routers: vec![classify_router, generate_router],
            drainer: Some(drainer),
            classify_leader: Some(classify_leader),
            generate_leader: Some(generate_leader),
        })
    }

    /// The bound address (resolves `:0` bindings).
    pub fn local_addr(&self) -> SocketAddr {
        self.inner.local_addr
    }

    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle { inner: Arc::clone(&self.inner) }
    }

    /// Wait for the gateway to drain (a [`ShutdownHandle::shutdown`]
    /// or `/admin/shutdown` must flip it) and join every thread,
    /// returning the leaders' final outcomes.
    pub fn join(mut self) -> Result<GatewayReport> {
        let classify_res = self
            .classify_leader
            .take()
            .expect("join once")
            .join()
            .expect("classify leader panicked");
        let generate_res = self
            .generate_leader
            .take()
            .expect("join once")
            .join()
            .expect("generate leader panicked");
        // Both leaders have exited: every reply they will ever emit is
        // in the router channels. On the error path (a leader died with
        // work in flight) the in-flight counters never reach zero, so
        // force the stop here instead of relying on the drainer.
        self.inner.state.store(STOPPED, Ordering::SeqCst);
        poke_listener(self.inner.local_addr);
        for r in self.routers.drain(..) {
            r.join().expect("router panicked");
        }
        if let Some(d) = self.drainer.take() {
            d.join().expect("drainer panicked");
        }
        if let Some(a) = self.accept.take() {
            a.join().expect("accept loop panicked");
        }
        for w in self.workers.drain(..) {
            w.join().expect("conn worker panicked");
        }
        let stats = &self.inner.stats;
        Ok(GatewayReport {
            classify: classify_res?,
            generate: generate_res?,
            connections: stats.connections_total.load(Ordering::Relaxed),
            http_requests: stats.http_requests_total.load(Ordering::Relaxed),
            shed: stats.shed_total.load(Ordering::Relaxed),
        })
    }

    /// Convenience: begin a drain and wait it out.
    pub fn shutdown(self) -> Result<GatewayReport> {
        self.inner.begin_drain();
        self.join()
    }
}

/// Wake a (possibly) blocked accept loop by connecting to it, retrying
/// until the listener is really gone — a single poke can be absorbed
/// without an accept iteration when the bounded worker handoff is full.
fn poke_listener(addr: SocketAddr) {
    for _ in 0..100 {
        if TcpStream::connect(addr).is_err() {
            return; // listener closed: accept loop has exited
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

// ---------------------------------------------------------------------
// connection handling
// ---------------------------------------------------------------------

/// Guard that tracks one in-flight HTTP request for the drain barrier.
struct ActiveGuard<'a>(&'a AtomicUsize);

impl<'a> ActiveGuard<'a> {
    fn new(counter: &'a AtomicUsize) -> Self {
        counter.fetch_add(1, Ordering::SeqCst);
        Self(counter)
    }
}

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

fn handle_conn(inner: &Arc<Inner>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    // short read timeout: the loop uses it as a tick to notice
    // drain/stop and idle expiry without a dedicated timer thread
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut parser = RequestParser::new(inner.cfg.max_body);
    let mut buf = [0u8; 8192];
    let mut idle_since = Instant::now();
    loop {
        // serve every fully-buffered request first (pipelining)
        match parser.take() {
            Ok(Some(req)) => {
                idle_since = Instant::now();
                match handle_request(inner, &mut stream, req) {
                    Ok(true) => continue,
                    _ => return, // close requested or socket error
                }
            }
            Ok(None) => {}
            Err(e) => {
                // framing is broken: answer and close
                inner.stats.bad_requests_total.fetch_add(1, Ordering::Relaxed);
                let _ = respond_json(inner, &mut stream, e.status(), &error_body(&e.to_string()));
                return;
            }
        }
        match stream.read(&mut buf) {
            Ok(0) => return, // peer closed
            Ok(n) => parser.push(&buf[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                let state = inner.state.load(Ordering::SeqCst);
                if state == STOPPED {
                    return;
                }
                // during a drain, idle keep-alive connections close so
                // the worker pool can wind down; a half-received
                // request still gets its read
                if state == DRAINING && parser.buffered() == 0 {
                    return;
                }
                if idle_since.elapsed() > inner.cfg.keep_alive_idle {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

/// Dispatch one parsed request. Returns `Ok(true)` to keep the
/// connection open.
fn handle_request(inner: &Arc<Inner>, stream: &mut TcpStream, req: Request) -> io::Result<bool> {
    inner.stats.http_requests_total.fetch_add(1, Ordering::Relaxed);
    let _active = ActiveGuard::new(&inner.active_requests);
    let keep = req.keep_alive();
    const ROUTES: [&str; 5] =
        ["/healthz", "/metrics", "/v1/classify", "/v1/generate", "/admin/shutdown"];
    match (req.method.as_str(), req.path()) {
        ("GET", "/healthz") => handle_healthz(inner, stream)?,
        ("GET", "/metrics") => handle_metrics(inner, stream)?,
        ("POST", "/v1/classify") => handle_classify(inner, stream, &req)?,
        ("POST", "/v1/generate") => {
            let streamed_ok = handle_generate(inner, stream, &req)?;
            return Ok(keep && streamed_ok);
        }
        ("POST", "/admin/shutdown") => {
            inner.begin_drain();
            respond_json(inner, stream, 200, "{\"status\":\"draining\"}")?;
        }
        (_, path) if ROUTES.contains(&path) => {
            respond_json(inner, stream, 405, &error_body("method not allowed"))?;
        }
        _ => respond_json(inner, stream, 404, &error_body("no such route"))?,
    }
    Ok(keep)
}

fn handle_healthz(inner: &Arc<Inner>, stream: &mut TcpStream) -> io::Result<()> {
    let draining = inner.state() != RUNNING;
    let body = format!(
        "{{\"status\":\"{}\",\"seq_len\":{},\"vocab\":{},\"n_classes\":{},\"replicas\":{}}}",
        if draining { "draining" } else { "ok" },
        inner.server.seq_len(),
        inner.server.vocab(),
        inner.server.n_classes(),
        inner.cfg.replicas
    );
    respond_json(inner, stream, if draining { 503 } else { 200 }, &body)
}

fn handle_metrics(inner: &Arc<Inner>, stream: &mut TcpStream) -> io::Result<()> {
    let body = metrics_body(inner);
    let code = 200;
    inner.stats.record_status(code);
    http::write_response(
        stream,
        code,
        &[("Content-Type", "text/plain; version=0.0.4")],
        body.as_bytes(),
    )
}

/// Render the Prometheus exposition: tier rows straight from
/// [`Server::live_snapshot`] (the same [`MetricRow`]s the CLI prints),
/// then gateway-level counters, then per-shard plan-cache stats.
fn metrics_body(inner: &Arc<Inner>) -> String {
    let mut out = String::new();
    for row in inner.server.live_snapshot().rows() {
        out.push_str("esact_");
        out.push_str(&row.to_string());
        out.push('\n');
    }
    let s = &inner.stats;
    let http_lat = inner.classify_latencies.lock().unwrap().percentiles();
    let gw_rows = [
        MetricRow::of("gateway_state", inner.state() as f64),
        MetricRow::of("gateway_uptime_seconds", inner.started.elapsed().as_secs_f64()),
        MetricRow::of(
            "gateway_connections_total",
            s.connections_total.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of(
            "gateway_http_requests_total",
            s.http_requests_total.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of(
            "gateway_responses_2xx_total",
            s.responses_2xx.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of(
            "gateway_responses_4xx_total",
            s.responses_4xx.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of(
            "gateway_responses_5xx_total",
            s.responses_5xx.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of("gateway_shed_total", s.shed_total.load(Ordering::Relaxed) as f64),
        MetricRow::of(
            "gateway_bad_requests_total",
            s.bad_requests_total.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of("gateway_streams_total", s.streams_total.load(Ordering::Relaxed) as f64),
        MetricRow::of(
            "gateway_stream_tokens_total",
            s.stream_tokens_total.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of("gateway_classify_in_flight", inner.classify.in_flight() as f64),
        MetricRow::of("gateway_generate_in_flight", inner.generate.in_flight() as f64),
        MetricRow::of(
            "gateway_active_requests",
            inner.active_requests.load(Ordering::SeqCst) as f64,
        ),
        MetricRow::of("gateway_classify_http_p50_seconds", http_lat.0),
        MetricRow::of("gateway_classify_http_p99_seconds", http_lat.1),
    ];
    for row in gw_rows {
        out.push_str("esact_");
        out.push_str(&row.to_string());
        out.push('\n');
    }
    for (i, shard) in inner.server.plan_cache_shard_stats().iter().enumerate() {
        let rows = [
            MetricRow::labeled("plan_cache_shard_entries", "shard", i, shard.entries as f64),
            MetricRow::labeled("plan_cache_shard_hits_total", "shard", i, shard.hits as f64),
            MetricRow::labeled("plan_cache_shard_misses_total", "shard", i, shard.misses as f64),
            MetricRow::labeled(
                "plan_cache_shard_step_entries",
                "shard",
                i,
                shard.step_entries as f64,
            ),
        ];
        for row in rows {
            out.push_str("esact_");
            out.push_str(&row.to_string());
            out.push('\n');
        }
    }
    out
}

fn handle_classify(inner: &Arc<Inner>, stream: &mut TcpStream, req: &Request) -> io::Result<()> {
    let t0 = Instant::now();
    let batch = match parse_classify_body(inner, &req.body) {
        Ok(batch) => batch,
        Err(msg) => return respond_json(inner, stream, 400, &error_body(&msg)),
    };
    if inner.state() != RUNNING {
        return respond_json(inner, stream, 503, &error_body("gateway is draining"));
    }
    let k = batch.len();
    // a batch that can never fit the admission bound is a terminal
    // client error, not a retryable 429 (retrying it would loop forever)
    if k > inner.cfg.policy.max_queue {
        let msg =
            format!("batch of {k} exceeds the admission bound {}", inner.cfg.policy.max_queue);
        return respond_json(inner, stream, 400, &error_body(&msg));
    }
    // the real bound: the same max_queue the leader stops pulling at —
    // beyond it the tier is saturated and queueing would be unbounded
    if !inner.classify.try_admit(k, inner.cfg.policy.max_queue) {
        return respond_with(
            inner,
            stream,
            429,
            &[("Retry-After", "1"), ("Content-Type", "application/json")],
            error_body("serving queue is full").as_bytes(),
        );
    }
    let (ids, rx) = inner.classify.register(k);
    let arrived = Instant::now();
    let requests: Vec<ClassifyRequest> = ids
        .iter()
        .zip(batch)
        .map(|(&id, tokens)| ClassifyRequest { id, tokens, arrived })
        .collect();
    if !inner.classify.send_all(requests) {
        inner.classify.unregister(&ids);
        inner.classify.release(k);
        return respond_json(inner, stream, 503, &error_body("serving tier unavailable"));
    }
    let mut by_id: HashMap<u64, Reply> = HashMap::with_capacity(k);
    let deadline = Instant::now() + inner.cfg.request_timeout;
    while by_id.len() < k {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            break;
        }
        match rx.recv_timeout(remaining) {
            Ok(reply) => {
                by_id.insert(reply.id, reply);
            }
            Err(_) => break,
        }
    }
    if by_id.len() < k {
        inner.classify.unregister(&ids);
        return respond_json(inner, stream, 500, &error_body("timed out on the serving tier"));
    }
    let mut body = String::from("{\"logits\":[");
    for (i, id) in ids.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&json::f32_array(&by_id[id].logits));
    }
    body.push_str("],\"latency_ms\":[");
    for (i, id) in ids.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&format!("{:.3}", by_id[id].latency.as_secs_f64() * 1e3));
    }
    body.push_str("]}");
    inner.record_classify_latency(t0.elapsed().as_secs_f64());
    respond_json(inner, stream, 200, &body)
}

/// Validate and extract the classify batch: `{"tokens": [[...], ...]}`
/// (a single flat `[...]` is accepted as a batch of one).
fn parse_classify_body(inner: &Arc<Inner>, body: &[u8]) -> Result<Vec<Vec<i32>>, String> {
    let text =
        std::str::from_utf8(body).map_err(|_| "body is not valid UTF-8".to_string())?;
    let doc = Json::parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let tokens = doc.get("tokens").ok_or("missing \"tokens\" field")?;
    let arr = tokens.as_arr().ok_or("\"tokens\" must be an array")?;
    let nested = arr.first().is_some_and(|x| x.as_arr().is_some());
    let seqs: Vec<&Json> = if nested { arr.iter().collect() } else { vec![tokens] };
    if seqs.is_empty() {
        return Err("empty batch".to_string());
    }
    if seqs.len() > MAX_BATCH_PER_REQUEST {
        return Err(format!("batch larger than {MAX_BATCH_PER_REQUEST}"));
    }
    let (l, vocab) = (inner.server.seq_len(), inner.server.vocab() as i32);
    seqs.iter()
        .map(|s| {
            let toks = json::to_i32_vec(s).ok_or("tokens must be an array of integers")?;
            if toks.len() != l {
                return Err(format!("sequence length {} != compiled L {l}", toks.len()));
            }
            if let Some(bad) = toks.iter().find(|&&t| t < 0 || t >= vocab) {
                return Err(format!("token id {bad} outside vocab 0..{vocab}"));
            }
            Ok(toks)
        })
        .collect()
}

/// Stream one generation. Returns `Ok(false)` when the connection
/// must close (stream aborted mid-way — framing no longer clean).
fn handle_generate(
    inner: &Arc<Inner>,
    stream: &mut TcpStream,
    req: &Request,
) -> io::Result<bool> {
    let (prompt, max_new, sampling) = match parse_generate_body(inner, &req.body) {
        Ok(parsed) => parsed,
        Err(msg) => {
            respond_json(inner, stream, 400, &error_body(&msg))?;
            return Ok(true);
        }
    };
    if inner.state() != RUNNING {
        respond_json(inner, stream, 503, &error_body("gateway is draining"))?;
        return Ok(true);
    }
    if !inner.generate.try_admit(1, inner.cfg.max_sessions) {
        respond_with(
            inner,
            stream,
            429,
            &[("Retry-After", "1"), ("Content-Type", "application/json")],
            error_body("all generate sessions are busy").as_bytes(),
        )?;
        return Ok(true);
    }
    let (ids, rx) = inner.generate.register(1);
    let id = ids[0];
    let request = GenRequest { id, prompt, max_new, sampling, arrived: Instant::now() };
    if !inner.generate.send_all(vec![request]) {
        inner.generate.unregister(&ids);
        inner.generate.release(1);
        respond_json(inner, stream, 503, &error_body("serving tier unavailable"))?;
        return Ok(true);
    }
    inner.stats.streams_total.fetch_add(1, Ordering::Relaxed);
    inner.stats.record_status(200);
    let mut w =
        ChunkedWriter::begin(stream, 200, &[("Content-Type", "application/x-ndjson")])?;
    loop {
        match rx.recv_timeout(inner.cfg.request_timeout) {
            Ok(chunk) => {
                inner
                    .stats
                    .stream_tokens_total
                    .fetch_add(chunk.tokens.len(), Ordering::Relaxed);
                // prefill slices may be empty; only data or the final
                // marker go on the wire
                if !chunk.tokens.is_empty() || chunk.done {
                    let line = format!(
                        "{{\"tokens\":{},\"done\":{}}}\n",
                        json::i32_array(&chunk.tokens),
                        chunk.done
                    );
                    w.chunk(line.as_bytes())?;
                }
                if chunk.done {
                    w.finish()?;
                    return Ok(true);
                }
            }
            Err(_) => {
                // tier died or stalled past the timeout: emit a final
                // error line, close the connection (framing preserved
                // by the chunked terminator)
                inner.generate.unregister(&ids);
                let _ = w.chunk(b"{\"error\":\"decode tier stalled\",\"done\":true}\n");
                let _ = w.finish();
                return Ok(false);
            }
        }
    }
}

type GenerateParams = (Vec<i32>, usize, Sampling);

/// Validate `/v1/generate` bodies:
/// `{"prompt": [...], "max_new": n, "top_k": k?, "temperature": t?, "seed": s?}`.
fn parse_generate_body(inner: &Arc<Inner>, body: &[u8]) -> Result<GenerateParams, String> {
    let text =
        std::str::from_utf8(body).map_err(|_| "body is not valid UTF-8".to_string())?;
    let doc = Json::parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let prompt = json::to_i32_vec(doc.get("prompt").ok_or("missing \"prompt\" field")?)
        .ok_or("\"prompt\" must be an array of integers")?;
    if prompt.is_empty() {
        return Err("empty prompt".to_string());
    }
    if prompt.len() > MAX_NEW_CAP {
        return Err(format!("prompt longer than {MAX_NEW_CAP}"));
    }
    let vocab = inner.server.vocab() as i32;
    if let Some(bad) = prompt.iter().find(|&&t| t < 0 || t >= vocab) {
        return Err(format!("token id {bad} outside vocab 0..{vocab}"));
    }
    let max_new = match doc.get("max_new") {
        None => 16,
        Some(v) => v.as_usize().ok_or("\"max_new\" must be a non-negative integer")?,
    };
    if max_new > MAX_NEW_CAP {
        return Err(format!("max_new larger than {MAX_NEW_CAP}"));
    }
    let sampling = match doc.get("top_k") {
        None => Sampling::Greedy,
        Some(v) => {
            let k = v.as_usize().filter(|&k| k >= 1).ok_or("\"top_k\" must be >= 1")?;
            let temperature = match doc.get("temperature") {
                None => 1.0,
                Some(t) => t.as_f64().filter(|t| *t > 0.0).ok_or("bad \"temperature\"")? as f32,
            };
            let seed = match doc.get("seed") {
                None => 0,
                Some(s) => s.as_i64().filter(|s| *s >= 0).ok_or("bad \"seed\"")? as u64,
            };
            Sampling::TopK { k, temperature, seed }
        }
    };
    Ok((prompt, max_new, sampling))
}

fn error_body(msg: &str) -> String {
    Json::Obj(vec![("error".to_string(), Json::Str(msg.to_string()))]).encode()
}

fn respond_json(
    inner: &Arc<Inner>,
    stream: &mut TcpStream,
    code: u16,
    body: &str,
) -> io::Result<()> {
    respond_with(
        inner,
        stream,
        code,
        &[("Content-Type", "application/json")],
        body.as_bytes(),
    )
}

fn respond_with(
    inner: &Arc<Inner>,
    stream: &mut TcpStream,
    code: u16,
    headers: &[(&str, &str)],
    body: &[u8],
) -> io::Result<()> {
    inner.stats.record_status(code);
    http::write_response(stream, code, headers, body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SplsConfig;
    use crate::net::client::{classify_body, HttpClient};
    use crate::util::rng::Xoshiro256pp;
    use std::io::Write;
    use std::path::Path;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn start_gateway(cfg: GatewayConfig) -> (Gateway, String) {
        let srv =
            Arc::new(Server::new(&artifacts_dir(), cfg.mode, SplsConfig::default()).unwrap());
        let gw = Gateway::start(srv, cfg).unwrap();
        let addr = gw.local_addr().to_string();
        (gw, addr)
    }

    fn seqs(n: usize, l: usize) -> Vec<Vec<i32>> {
        let mut rng = Xoshiro256pp::new(5);
        (0..n).map(|_| crate::model::synth::gen_example(&mut rng, l).0).collect()
    }

    /// Read one full response off a raw socket (status line + head +
    /// best-effort body) as text.
    fn read_response_text(s: &mut TcpStream) -> String {
        let _ = s.set_read_timeout(Some(Duration::from_secs(5)));
        let mut buf = Vec::new();
        let mut tmp = [0u8; 2048];
        while !buf.windows(4).any(|w| w == b"\r\n\r\n") {
            match s.read(&mut tmp) {
                Ok(0) => break,
                Ok(n) => buf.extend_from_slice(&tmp[..n]),
                Err(_) => break,
            }
        }
        String::from_utf8_lossy(&buf).to_string()
    }

    #[test]
    fn healthz_metrics_and_unknown_routes_over_one_keepalive_conn() {
        let (gw, addr) = start_gateway(GatewayConfig::default());
        let mut c = HttpClient::connect(&addr).unwrap();
        let h = c.get("/healthz").unwrap();
        assert_eq!(h.status, 200);
        let doc = h.json().unwrap();
        assert_eq!(doc.get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(doc.get("seq_len").unwrap().as_usize(), Some(64));
        assert_eq!(doc.get("vocab").unwrap().as_usize(), Some(64));
        // the same connection serves further exchanges (keep-alive)
        let m = c.get("/metrics").unwrap();
        assert_eq!(m.status, 200);
        let text = String::from_utf8(m.body).unwrap();
        for needle in [
            "esact_serve_requests_total",
            "esact_generate_tokens_total",
            "esact_plan_cache_hit_rate",
            "esact_gateway_http_requests_total",
            "esact_replica_busy_seconds",
            "esact_plan_cache_shard_entries{shard=\"0\"}",
        ] {
            assert!(text.contains(needle), "metrics missing {needle}:\n{text}");
        }
        assert_eq!(c.get("/nope").unwrap().status, 404);
        assert_eq!(c.post_json("/healthz", "{}").unwrap().status, 405);
        gw.shutdown().unwrap();
    }

    #[test]
    fn classify_validates_input_before_the_executor_can_panic() {
        let (gw, addr) = start_gateway(GatewayConfig::default());
        let mut c = HttpClient::connect(&addr).unwrap();
        let pool = seqs(2, 64);
        let body = classify_body(&[&pool[0][..], &pool[1][..]]);
        let ok = c.post_json("/v1/classify", &body).unwrap();
        assert_eq!(ok.status, 200);
        let doc = ok.json().unwrap();
        let logits = doc.get("logits").unwrap().as_arr().unwrap();
        assert_eq!(logits.len(), 2);
        assert!(logits.iter().all(|row| row.as_arr().unwrap().len() == 16));
        let bad_bodies: Vec<String> = vec![
            "{not json".to_string(),
            "{\"tokens\": 3}".to_string(),
            "{}".to_string(),
            "{\"tokens\": []}".to_string(),
            "{\"tokens\": [[1.5, 2]]}".to_string(),
            classify_body(&[&vec![0i32; 10][..]]),    // wrong L
            classify_body(&[&vec![9999i32; 64][..]]), // out of vocab
        ];
        for bad in &bad_bodies {
            let r = c.post_json("/v1/classify", bad).unwrap();
            assert_eq!(r.status, 400, "{bad:?}");
        }
        // the gateway is still healthy after all that abuse
        assert_eq!(c.get("/healthz").unwrap().status, 200);
        gw.shutdown().unwrap();
    }

    #[test]
    fn raw_socket_abuse_gets_clean_http_errors() {
        let (gw, addr) = start_gateway(GatewayConfig::default());
        // invalid UTF-8 body → 400, connection stays usable
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"POST /v1/classify HTTP/1.1\r\nContent-Length: 2\r\n\r\n\xff\xfe")
            .unwrap();
        let text = read_response_text(&mut s);
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
        // garbage request line → 400 and close
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"GARBAGE\r\n\r\n").unwrap();
        let text = read_response_text(&mut s);
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
        // oversized declared body → 413
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"POST /v1/classify HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n")
            .unwrap();
        let text = read_response_text(&mut s);
        assert!(text.starts_with("HTTP/1.1 413"), "{text}");
        // two pipelined requests in one segment → two responses in order
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"GET /healthz HTTP/1.1\r\n\r\nGET /nope HTTP/1.1\r\n\r\n").unwrap();
        std::thread::sleep(Duration::from_millis(300));
        let mut buf = Vec::new();
        let _ = s.set_read_timeout(Some(Duration::from_millis(500)));
        let mut tmp = [0u8; 4096];
        while let Ok(n) = s.read(&mut tmp) {
            if n == 0 {
                break;
            }
            buf.extend_from_slice(&tmp[..n]);
        }
        let text = String::from_utf8_lossy(&buf).to_string();
        let first = text.find("HTTP/1.1 200").expect("healthz response");
        let second = text.find("HTTP/1.1 404").expect("pipelined 404 response");
        assert!(first < second, "pipelined responses must come back in order");
        gw.shutdown().unwrap();
    }

    #[test]
    fn saturation_sheds_with_429_retry_after_and_counts_it() {
        use std::sync::atomic::AtomicUsize;
        // admission bound 1: concurrent posts must overlap and shed
        let cfg = GatewayConfig {
            policy: BatchPolicy { max_queue: 1, ..Default::default() },
            max_conns: 12,
            ..Default::default()
        };
        let (gw, addr) = start_gateway(cfg);
        let pool = Arc::new(seqs(4, 64));
        let ok = Arc::new(AtomicUsize::new(0));
        let shed = Arc::new(AtomicUsize::new(0));
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let (pool, ok, shed) = (Arc::clone(&pool), Arc::clone(&ok), Arc::clone(&shed));
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = HttpClient::connect(&addr).unwrap();
                    for i in 0..4 {
                        let body = classify_body(&[&pool[i % pool.len()][..]]);
                        let r = c.post_json("/v1/classify", &body).unwrap();
                        match r.status {
                            200 => {
                                ok.fetch_add(1, Ordering::Relaxed);
                            }
                            429 => {
                                assert_eq!(
                                    r.header("retry-after"),
                                    Some("1"),
                                    "429 must carry Retry-After"
                                );
                                shed.fetch_add(1, Ordering::Relaxed);
                            }
                            other => panic!("unexpected status {other}"),
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let (ok, shed) = (ok.load(Ordering::Relaxed), shed.load(Ordering::Relaxed));
        assert_eq!(ok + shed, 32, "every post must be answered");
        assert!(ok >= 1, "the first admit must always succeed");
        assert!(shed >= 1, "8 racing connections over bound 1 must shed");
        // /metrics reports the same shed count
        let mut c = HttpClient::connect(&addr).unwrap();
        let text = String::from_utf8(c.get("/metrics").unwrap().body).unwrap();
        let line = text
            .lines()
            .find(|l| l.starts_with("esact_gateway_shed_total"))
            .expect("shed metric");
        let value: f64 = line.split_whitespace().last().unwrap().parse().unwrap();
        assert_eq!(value as usize, shed, "metrics and HTTP answers must agree");
        gw.shutdown().unwrap();
    }

    #[test]
    fn admin_shutdown_drains_and_closes_the_listener() {
        let (gw, addr) = start_gateway(GatewayConfig::default());
        let mut c = HttpClient::connect(&addr).unwrap();
        assert_eq!(c.post_json("/admin/shutdown", "").unwrap().status, 200);
        let report = gw.join().unwrap();
        assert_eq!(report.classify.metrics.requests, 0);
        assert_eq!(report.generate.metrics.sessions, 0);
        assert!(report.http_requests >= 1);
        // the listener is gone: fresh connections must start failing
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if TcpStream::connect(&addr).is_err() {
                break;
            }
            assert!(Instant::now() < deadline, "listener still accepting after drain");
            std::thread::sleep(Duration::from_millis(50));
        }
    }
}
