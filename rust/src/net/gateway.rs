//! The HTTP serving gateway: a single nonblocking readiness event loop
//! (raw `epoll` via [`crate::net::poll`]) owning every client socket,
//! routing requests onto the replicated serving tier through the
//! unified [`TierHandle`] submit/complete interface. std-only by
//! construction (no tokio/hyper/serde in the vendored crate set, see
//! DESIGN.md §Environment).
//!
//! Architecture (one process, one event thread):
//!
//! ```text
//! clients ──TCP──▶ epoll loop ── per-conn state machine (net::conn)
//!                    │  accept / read / parse / flush, thousands of
//!                    │  sockets; a conn with a job in flight is
//!                    │  *parked* (no read interest) not thread-blocked
//!                    ▼ TierHandle::submit (admission-bounded)
//!   classify leader: Server::serve_replicated ─┐ completions queue
//!   generate leader: Server::serve_generate   ─┤   + eventfd wakeup
//!                    (long-lived, channel-fed) ┘ ──▶ loop resumes conn
//! ```
//!
//! * `POST /v1/classify` — batched classification through
//!   `serve_replicated`'s admission + continuous-batching path.
//! * `POST /v1/generate` — `Transfer-Encoding: chunked` streaming of
//!   generate slices as they leave the decode batcher, drained through
//!   the loop without blocking it.
//! * `GET /metrics` — Prometheus text: the live tier snapshot rendered
//!   through the same [`MetricRow`]s the CLI `Display` impls print
//!   (one source of truth), plus gateway-level counters and per-shard
//!   plan-cache stats.
//! * `GET /healthz` — readiness (flips to `503 draining` on shutdown).
//! * `GET /debug/trace?n=` — the last N completed request trace spans
//!   (stage timestamps, retry lineage) as JSON, newest first.
//! * `POST /admin/shutdown` — begin a graceful drain remotely.
//!
//! **Backpressure is wired to the real bound**: [`TierHandle`] admits
//! against the same `BatchPolicy::max_queue` the classify leader stops
//! pulling at (and `max_sessions` for generate), so instead of queueing
//! unboundedly the gateway answers `429` with `Retry-After` the moment
//! the tier is saturated. `max_conns` bounds concurrent *sockets*, not
//! threads: at the cap the listener pauses and fresh connections wait
//! in the TCP backlog.
//!
//! **Every non-2xx response carries one error envelope**:
//! `{"error":{"code":...,"message":...}}`, with `retry_after_ms` on
//! 429s. The codes are stable API surface (see README §Error codes).
//!
//! **Graceful shutdown** ([`ShutdownHandle`]): flag flip → `/healthz`
//! reports draining and new work gets 503 → the tier lanes close →
//! in-flight batches and generate streams run to completion and flush →
//! the loop exits and the listener closes. The leaders' final
//! [`ServeOutcome`]/[`GenerateOutcome`] come back from
//! [`Gateway::join`].

use std::collections::HashMap;
use std::fmt;
use std::io::ErrorKind;
use std::mem;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{
    paged_rows, BatchPolicy, Completion, GenerateOutcome, MetricRow, Mode, ServeOutcome, Server,
    StreamFault, Submission, SubmitError, Tier, TierConfig, TierHandle,
};
use crate::decode::{DecodeConfig, Sampling};
use crate::net::conn::{Conn, ConnState};
use crate::net::http::{self, Request};
use crate::net::json::{self, Json};
use crate::net::poll::{Event, Interest, Poller, Waker};
use crate::obs::prom::{help_for, PromWriter};
use crate::util::fault::FaultSite;
use crate::util::stats::LatencyWindow;

/// Gateway lifecycle states.
const RUNNING: u8 = 0;
const DRAINING: u8 = 1;
const STOPPED: u8 = 2;

/// Largest classify batch one HTTP request may carry.
pub const MAX_BATCH_PER_REQUEST: usize = 64;

/// Largest `max_new` one generate request may ask for.
pub const MAX_NEW_CAP: usize = 1024;

/// Reserved poll tokens; connections start above them.
const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// Event-loop heartbeat: the longest the loop sleeps before running
/// timers (idle expiry, request deadlines, drain progress).
const TICK: Duration = Duration::from_millis(25);

/// During a drain, idle keep-alive sockets get this long to deliver a
/// final request (health probes race the drain) before closing.
const DRAIN_GRACE: Duration = Duration::from_millis(100);

/// In-band stream error line for a stalled decode tier — same envelope
/// shape as the HTTP-level errors, delivered as the final NDJSON line.
const STREAM_STALL_LINE: &str =
    "{\"error\":{\"code\":\"tier_timeout\",\"message\":\"decode tier stalled\"},\"done\":true}\n";

/// Default 429 back-off hint (milliseconds) — used by
/// [`GatewayConfig`] and by error bodies rendered outside a request
/// context (before the config is reachable).
const DEFAULT_RETRY_AFTER_MS: u64 = 1000;

/// Gateway deployment knobs. Build via [`GatewayConfig::builder`],
/// which validates every bound before the gateway can bind a socket.
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// Bind address (`127.0.0.1:0` picks an ephemeral port).
    pub addr: String,
    /// Concurrent **sockets** (not threads) the loop will hold open; at
    /// the cap the listener pauses and fresh connections queue in the
    /// TCP backlog. Default 1024.
    pub max_conns: usize,
    /// Replicas per tier (classify and generate each own a pool).
    pub replicas: usize,
    /// Classify execution mode of the backing server.
    pub mode: Mode,
    /// Leader batching policy; `max_queue` doubles as the 429 bound.
    pub policy: BatchPolicy,
    /// Decode configuration for `/v1/generate` sessions.
    pub decode: DecodeConfig,
    /// Decode steps per dispatched slice (continuous batching grain).
    pub steps_per_slice: usize,
    /// Steps per dispatched slice while a session is still prefilling
    /// its prompt (chunked prefill); 0 falls back to `steps_per_slice`.
    pub prefill_chunk: usize,
    /// Live generate sessions admitted before 429.
    pub max_sessions: usize,
    /// Request-body cap (413 beyond it).
    pub max_body: usize,
    /// How long a parked request may sit on the tier before the
    /// gateway answers 500 (classify) or ends the stream (generate).
    pub request_timeout: Duration,
    /// Connections idle since their last completed request are reaped
    /// after this — the slow-loris bound. Default 10s.
    pub idle_timeout: Duration,
    /// Kernel events decoded per `epoll_wait` call. Default 256.
    pub max_events: usize,
    /// Back-off hint carried by 429 responses: the `retry_after_ms`
    /// envelope field verbatim, and the `Retry-After` header rounded
    /// up to whole seconds. Default 1000.
    pub retry_after_ms: u64,
    /// Trace-span sampling: record a span for 1-in-N requests (1 =
    /// every request, 0 = tracing off). Latency histograms are never
    /// sampled. Default 1.
    pub trace_sample: u64,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            max_conns: 1024,
            replicas: 1,
            mode: Mode::Dense,
            policy: BatchPolicy::default(),
            decode: DecodeConfig::default(),
            steps_per_slice: 4,
            prefill_chunk: 0,
            max_sessions: 16,
            max_body: http::DEFAULT_MAX_BODY,
            request_timeout: Duration::from_secs(30),
            idle_timeout: Duration::from_secs(10),
            max_events: 256,
            retry_after_ms: DEFAULT_RETRY_AFTER_MS,
            trace_sample: 1,
        }
    }
}

impl GatewayConfig {
    /// Start from the documented defaults and override what you need.
    pub fn builder() -> GatewayConfigBuilder {
        GatewayConfigBuilder { cfg: GatewayConfig::default() }
    }
}

/// Validating builder for [`GatewayConfig`] — the only constructor the
/// CLI, examples, benches, and tests go through. [`build`] refuses
/// zero-valued bounds instead of letting them wedge the event loop.
///
/// [`build`]: GatewayConfigBuilder::build
#[derive(Clone, Debug)]
pub struct GatewayConfigBuilder {
    cfg: GatewayConfig,
}

impl GatewayConfigBuilder {
    pub fn addr(mut self, addr: impl Into<String>) -> Self {
        self.cfg.addr = addr.into();
        self
    }

    pub fn max_conns(mut self, n: usize) -> Self {
        self.cfg.max_conns = n;
        self
    }

    pub fn replicas(mut self, n: usize) -> Self {
        self.cfg.replicas = n;
        self
    }

    pub fn mode(mut self, mode: Mode) -> Self {
        self.cfg.mode = mode;
        self
    }

    pub fn policy(mut self, policy: BatchPolicy) -> Self {
        self.cfg.policy = policy;
        self
    }

    pub fn decode(mut self, decode: DecodeConfig) -> Self {
        self.cfg.decode = decode;
        self
    }

    pub fn steps_per_slice(mut self, n: usize) -> Self {
        self.cfg.steps_per_slice = n;
        self
    }

    pub fn prefill_chunk(mut self, n: usize) -> Self {
        self.cfg.prefill_chunk = n;
        self
    }

    pub fn max_sessions(mut self, n: usize) -> Self {
        self.cfg.max_sessions = n;
        self
    }

    pub fn max_body(mut self, bytes: usize) -> Self {
        self.cfg.max_body = bytes;
        self
    }

    pub fn request_timeout(mut self, d: Duration) -> Self {
        self.cfg.request_timeout = d;
        self
    }

    pub fn idle_timeout(mut self, d: Duration) -> Self {
        self.cfg.idle_timeout = d;
        self
    }

    pub fn max_events(mut self, n: usize) -> Self {
        self.cfg.max_events = n;
        self
    }

    pub fn retry_after_ms(mut self, ms: u64) -> Self {
        self.cfg.retry_after_ms = ms;
        self
    }

    pub fn trace_sample(mut self, n: u64) -> Self {
        self.cfg.trace_sample = n;
        self
    }

    /// Validate every knob. Zero-valued bounds are configuration bugs
    /// (a `max_conns` of 0 accepts nothing; a zero timeout reaps every
    /// socket on the first tick) and are refused here, not discovered
    /// in production behavior.
    pub fn build(self) -> Result<GatewayConfig> {
        let cfg = self.cfg;
        if cfg.max_conns == 0 {
            bail!("max_conns must be >= 1 (it bounds concurrent sockets, not worker threads)");
        }
        if cfg.replicas == 0 {
            bail!("replicas must be >= 1");
        }
        if cfg.policy.max_queue == 0 {
            bail!("policy.max_queue must be >= 1 (it is the 429 admission bound)");
        }
        if cfg.steps_per_slice == 0 {
            bail!("steps_per_slice must be >= 1");
        }
        if cfg.max_sessions == 0 {
            bail!("max_sessions must be >= 1");
        }
        if cfg.max_body == 0 {
            bail!("max_body must be >= 1 byte");
        }
        if cfg.request_timeout.is_zero() {
            bail!("request_timeout must be nonzero");
        }
        if cfg.idle_timeout.is_zero() {
            bail!("idle_timeout must be nonzero");
        }
        if cfg.max_events == 0 {
            bail!("max_events must be >= 1");
        }
        if cfg.retry_after_ms == 0 {
            bail!("retry_after_ms must be >= 1 (a zero hint tells clients to hammer the gateway)");
        }
        Ok(cfg)
    }
}

/// Gateway-level counters (the tier-level numbers come from
/// [`Server::live_snapshot`]).
#[derive(Default)]
struct GatewayStats {
    connections_total: AtomicUsize,
    /// Sockets currently held open by the loop (gauge).
    open_connections: AtomicUsize,
    /// Idle/slow-loris connections closed by the expiry sweep.
    conns_reaped_total: AtomicUsize,
    http_requests_total: AtomicUsize,
    responses_2xx: AtomicUsize,
    responses_4xx: AtomicUsize,
    responses_5xx: AtomicUsize,
    /// 429s from the admission bounds (subset of responses_4xx).
    shed_total: AtomicUsize,
    /// Requests the HTTP layer rejected before routing (parse/framing).
    bad_requests_total: AtomicUsize,
    streams_total: AtomicUsize,
    stream_tokens_total: AtomicUsize,
}

impl GatewayStats {
    fn record_status(&self, code: u16) {
        match code {
            200..=299 => &self.responses_2xx,
            400..=499 => &self.responses_4xx,
            _ => &self.responses_5xx,
        }
        .fetch_add(1, Ordering::Relaxed);
        if code == 429 {
            self.shed_total.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Stable machine-readable code for each error status the gateway can
/// produce — the `error.code` field of the envelope.
fn error_code(status: u16) -> &'static str {
    match status {
        400 => "bad_request",
        404 => "not_found",
        405 => "method_not_allowed",
        413 => "body_too_large",
        429 => "saturated",
        431 => "head_too_large",
        500 => "tier_timeout",
        501 => "unsupported_transfer",
        503 => "unavailable",
        505 => "http_version",
        _ => "error",
    }
}

/// Render the unified error envelope every non-2xx response carries:
/// `{"error":{"code":...,"message":...}}`, plus `retry_after_ms` on
/// 429s so clients can back off without parsing headers.
fn error_body(status: u16, msg: &str) -> String {
    error_body_coded(status, error_code(status), msg, DEFAULT_RETRY_AFTER_MS)
}

/// [`error_body`] with an explicit code, for statuses that map to more
/// than one failure class: a 500 is `tier_timeout` when the deadline
/// expired but `replica_fault` when the tier answered with a typed job
/// fault (retry budget exhausted on faulted replicas). The configured
/// `retry_after_ms` is rendered into 429 envelopes only.
fn error_body_coded(status: u16, code: &str, msg: &str, retry_after_ms: u64) -> String {
    let mut body = String::from("{\"error\":{\"code\":");
    body.push_str(&Json::Str(code.to_string()).encode());
    body.push_str(",\"message\":");
    body.push_str(&Json::Str(msg.to_string()).encode());
    if status == 429 {
        body.push_str(&format!(",\"retry_after_ms\":{retry_after_ms}"));
    }
    body.push_str("}}");
    body
}

/// State shared by the event loop, the shutdown handle, and `join`.
struct Inner {
    server: Arc<Server>,
    cfg: GatewayConfig,
    local_addr: SocketAddr,
    state: AtomicU8,
    stats: GatewayStats,
    /// The tier's submit/complete face; completions wake the loop via
    /// the eventfd notify installed at startup.
    tier: Arc<TierHandle>,
    /// HTTP requests currently parked on the tier (the drain barrier).
    active_requests: AtomicUsize,
    /// HTTP-level classify latencies for the /metrics gauge.
    classify_latencies: Mutex<LatencyWindow>,
    started: Instant,
    waker: Arc<Waker>,
}

impl Inner {
    fn state(&self) -> u8 {
        self.state.load(Ordering::SeqCst)
    }

    /// Flip to draining, close the tier lanes, and wake the loop so it
    /// notices immediately. Idempotent.
    fn begin_drain(&self) {
        if self
            .state
            .compare_exchange(RUNNING, DRAINING, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
        {
            self.tier.close();
        }
        self.waker.wake();
    }

    fn record_classify_latency(&self, seconds: f64) {
        self.classify_latencies.lock().unwrap().push(seconds);
    }
}

/// Handle for triggering a graceful drain from another thread (or from
/// the `/admin/shutdown` route).
#[derive(Clone)]
pub struct ShutdownHandle {
    inner: Arc<Inner>,
}

impl ShutdownHandle {
    /// Begin draining: `/healthz` flips to 503, new work is refused,
    /// in-flight work (including open generate streams) completes and
    /// flushes, then the listener closes. Returns immediately; use
    /// [`Gateway::join`] to wait for the drain to finish.
    pub fn shutdown(&self) {
        self.inner.begin_drain();
    }
}

/// Final accounting returned by [`Gateway::join`]: the leaders' joined
/// outcomes plus gateway-level totals.
#[derive(Debug)]
pub struct GatewayReport {
    pub classify: ServeOutcome,
    pub generate: GenerateOutcome,
    pub connections: usize,
    pub http_requests: usize,
    pub shed: usize,
}

impl fmt::Display for GatewayReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "gateway_connections_total                    {}", self.connections)?;
        writeln!(f, "gateway_http_requests_total                  {}", self.http_requests)?;
        writeln!(f, "gateway_shed_total                           {}", self.shed)?;
        write!(f, "{}{}", self.classify, self.generate)
    }
}

/// The running gateway: the serving [`Tier`] plus the one event-loop
/// thread that owns the listener and every client socket.
pub struct Gateway {
    inner: Arc<Inner>,
    tier: Option<Tier>,
    event_loop: Option<JoinHandle<()>>,
}

impl Gateway {
    /// Bind, spawn the serving tier, and start the event loop.
    pub fn start(server: Arc<Server>, cfg: GatewayConfig) -> Result<Gateway> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding gateway to {}", cfg.addr))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let local_addr = listener.local_addr()?;

        let tier = Tier::start(
            Arc::clone(&server),
            TierConfig {
                policy: cfg.policy,
                decode: cfg.decode,
                replicas: cfg.replicas,
                steps_per_slice: cfg.steps_per_slice,
                max_sessions: cfg.max_sessions,
                prefill_chunk: cfg.prefill_chunk,
                trace_sample: cfg.trace_sample,
            },
        )?;
        let handle = tier.handle();

        let poller = Poller::new(cfg.max_events).context("creating epoll instance")?;
        let waker = Arc::new(Waker::new(&poller, TOKEN_WAKER).context("creating eventfd waker")?);
        {
            // every completion nudges the loop out of epoll_wait
            let w = Arc::clone(&waker);
            handle.set_notify(move || w.wake());
        }

        let inner = Arc::new(Inner {
            server,
            local_addr,
            state: AtomicU8::new(RUNNING),
            stats: GatewayStats::default(),
            tier: handle,
            active_requests: AtomicUsize::new(0),
            classify_latencies: Mutex::new(LatencyWindow::default()),
            started: Instant::now(),
            waker,
            cfg,
        });

        let event_loop = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name("esact-http-loop".to_string())
                .spawn(move || EventLoop::new(inner, poller, listener).run())?
        };

        Ok(Gateway { inner, tier: Some(tier), event_loop: Some(event_loop) })
    }

    /// The bound address (resolves `:0` bindings).
    pub fn local_addr(&self) -> SocketAddr {
        self.inner.local_addr
    }

    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle { inner: Arc::clone(&self.inner) }
    }

    /// Wait for the gateway to drain (a [`ShutdownHandle::shutdown`]
    /// or `/admin/shutdown` must flip it) and join the tier and the
    /// event loop, returning the leaders' final outcomes.
    pub fn join(mut self) -> Result<GatewayReport> {
        let (classify_res, generate_res) = self.tier.take().expect("join once").join();
        if classify_res.is_err() || generate_res.is_err() {
            // a leader died with work parked: the loop's drain
            // condition (tier idle, buffers flushed) can never be met,
            // so force the stop. On the clean path the loop must reach
            // STOPPED itself — it still has final bytes to flush.
            self.inner.state.store(STOPPED, Ordering::SeqCst);
        }
        self.inner.waker.wake();
        if let Some(l) = self.event_loop.take() {
            l.join().expect("event loop panicked");
        }
        let stats = &self.inner.stats;
        Ok(GatewayReport {
            classify: classify_res?,
            generate: generate_res?,
            connections: stats.connections_total.load(Ordering::Relaxed),
            http_requests: stats.http_requests_total.load(Ordering::Relaxed),
            shed: stats.shed_total.load(Ordering::Relaxed),
        })
    }

    /// Convenience: begin a drain and wait it out.
    pub fn shutdown(self) -> Result<GatewayReport> {
        self.inner.begin_drain();
        self.join()
    }
}

// ---------------------------------------------------------------------
// the event loop
// ---------------------------------------------------------------------

/// What a parked connection is waiting on.
enum Pending {
    None,
    /// A classify batch: completions trickle in per id; the response
    /// renders once every id reported.
    Classify {
        ids: Vec<u64>,
        got: HashMap<u64, (Vec<f32>, Duration)>,
        t0: Instant,
        deadline: Instant,
        keep: bool,
    },
    /// A generate stream: chunks append to the out-buffer as they
    /// arrive; `deadline` refreshes per chunk (stall detection).
    Generate { id: u64, deadline: Instant, keep: bool },
}

struct ConnEntry {
    stream: TcpStream,
    conn: Conn,
    pending: Pending,
    interest: Interest,
    /// Still present in the epoll set (a parked conn whose peer hung
    /// up is taken out so the level-triggered RDHUP can't spin us).
    registered: bool,
    /// Peer half-closed: serve what was buffered, then tear down.
    peer_eof: bool,
}

struct EventLoop {
    inner: Arc<Inner>,
    poller: Poller,
    listener: TcpListener,
    /// Listener interest dropped because `max_conns` sockets are open.
    listener_paused: bool,
    conns: HashMap<u64, ConnEntry>,
    /// Tier job id → conn token (globally unique ids, one map).
    jobs: HashMap<u64, u64>,
    next_token: u64,
    /// Reused completion scratch buffer.
    completions: Vec<Completion>,
}

impl EventLoop {
    fn new(inner: Arc<Inner>, poller: Poller, listener: TcpListener) -> EventLoop {
        EventLoop {
            inner,
            poller,
            listener,
            listener_paused: false,
            conns: HashMap::new(),
            jobs: HashMap::new(),
            next_token: FIRST_CONN_TOKEN,
            completions: Vec::new(),
        }
    }

    fn run(mut self) {
        if self
            .poller
            .register(self.listener.as_raw_fd(), TOKEN_LISTENER, Interest::READ)
            .is_err()
        {
            self.inner.state.store(STOPPED, Ordering::SeqCst);
            return;
        }
        let mut events: Vec<Event> = Vec::new();
        loop {
            if self.inner.state() == STOPPED {
                break;
            }
            if self.poller.wait(&mut events, Some(TICK)).is_err() {
                break;
            }
            for &ev in &events {
                match ev.token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKER => self.inner.waker.drain(),
                    token => self.conn_event(token, ev),
                }
            }
            self.drain_completions();
            self.sweep();
            if self.inner.state() == DRAINING && self.try_finish_drain() {
                break;
            }
        }
        self.inner.state.store(STOPPED, Ordering::SeqCst);
        // listener and every socket drop here
    }

    /// Accept everything the backlog has, up to `max_conns` open
    /// sockets; at the cap, drop listener interest (resumed by
    /// `close_conn`) so the kernel backlog carries the overflow.
    /// Accepting continues during a drain — health probes need answers.
    fn accept_ready(&mut self) {
        loop {
            if self.conns.len() >= self.inner.cfg.max_conns {
                if !self.listener_paused
                    && self
                        .poller
                        .modify(self.listener.as_raw_fd(), TOKEN_LISTENER, Interest::NONE)
                        .is_ok()
                {
                    self.listener_paused = true;
                }
                return;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nodelay(true);
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let token = self.next_token;
                    self.next_token += 1;
                    if self.poller.register(stream.as_raw_fd(), token, Interest::READ).is_err() {
                        continue;
                    }
                    self.inner.stats.connections_total.fetch_add(1, Ordering::Relaxed);
                    self.inner.stats.open_connections.fetch_add(1, Ordering::Relaxed);
                    self.conns.insert(
                        token,
                        ConnEntry {
                            stream,
                            conn: Conn::new(self.inner.cfg.max_body, Instant::now()),
                            pending: Pending::None,
                            interest: Interest::READ,
                            registered: true,
                            peer_eof: false,
                        },
                    );
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(_) => return,
            }
        }
    }

    fn conn_event(&mut self, token: u64, ev: Event) {
        let mut dead = false;
        {
            let Some(entry) = self.conns.get_mut(&token) else { return };
            if ev.readable && entry.conn.wants_read() {
                match entry.conn.on_readable(&mut entry.stream) {
                    Ok(eof) => entry.peer_eof |= eof,
                    Err(_) => dead = true,
                }
            } else if ev.hangup {
                entry.peer_eof = true;
                if !entry.conn.wants_read() && !entry.conn.wants_write() && entry.registered {
                    // parked on a tier job with the peer's write side
                    // gone: nothing to poll for until the completion
                    // arrives, and the level-triggered RDHUP would spin
                    // the loop — take the fd out of the set for now
                    let _ = self.poller.deregister(entry.stream.as_raw_fd());
                    entry.registered = false;
                }
            }
        }
        if dead {
            self.close_conn(token);
        } else {
            self.advance_conn(token);
        }
    }

    /// Pull every complete pipelined request out of the parser and
    /// dispatch it, flushing between requests so the state machine can
    /// cycle Writing → KeepAlive → Reading without another socket
    /// event (the bytes are already ours; epoll won't re-report them).
    fn advance_conn(&mut self, token: u64) {
        loop {
            let req = {
                let Some(entry) = self.conns.get_mut(&token) else { return };
                if !matches!(entry.pending, Pending::None) {
                    break;
                }
                match entry.conn.next_request(Instant::now()) {
                    Ok(Some(req)) => req,
                    Ok(None) => break,
                    Err(e) => {
                        // framing is broken: answer with the envelope
                        // and close once it flushes
                        self.inner.stats.bad_requests_total.fetch_add(1, Ordering::Relaxed);
                        let status = e.status();
                        self.inner.stats.record_status(status);
                        let frame = http::render_response(
                            status,
                            &[("Content-Type", "application/json")],
                            error_body(status, &e.to_string()).as_bytes(),
                        );
                        entry.conn.enqueue(&frame);
                        entry.conn.mark_closing();
                        break;
                    }
                }
            };
            self.dispatch(token, req);
            self.flush_and_update(token);
        }
        // half-closed peer: everything it sent is dispatched or
        // incomplete; once no job is parked, tear the socket down
        let mark = self.conns.get(&token).is_some_and(|e| {
            e.peer_eof
                && matches!(e.pending, Pending::None)
                && matches!(e.conn.state(), ConnState::Reading | ConnState::KeepAlive)
        });
        if mark {
            if let Some(e) = self.conns.get_mut(&token) {
                e.conn.mark_closing();
            }
        }
        self.flush_and_update(token);
    }

    /// Route one parsed request.
    fn dispatch(&mut self, token: u64, req: Request) {
        self.inner.stats.http_requests_total.fetch_add(1, Ordering::Relaxed);
        let keep = req.keep_alive();
        const ROUTES: [&str; 6] = [
            "/healthz",
            "/metrics",
            "/debug/trace",
            "/v1/classify",
            "/v1/generate",
            "/admin/shutdown",
        ];
        match (req.method.as_str(), req.path()) {
            ("GET", "/healthz") => {
                let (code, body) = healthz_body(&self.inner);
                self.respond_json(token, code, &body, keep);
            }
            ("GET", "/debug/trace") => {
                let body = trace_body(&self.inner, &req);
                self.respond_json(token, 200, &body, keep);
            }
            ("GET", "/metrics") => {
                let body = metrics_body(&self.inner);
                self.respond(
                    token,
                    200,
                    &[("Content-Type", "text/plain; version=0.0.4")],
                    body.as_bytes(),
                    keep,
                );
            }
            ("POST", "/v1/classify") => self.dispatch_classify(token, &req, keep),
            ("POST", "/v1/generate") => self.dispatch_generate(token, &req, keep),
            ("POST", "/admin/shutdown") => {
                self.inner.begin_drain();
                self.respond_json(token, 200, "{\"status\":\"draining\"}", keep);
            }
            (_, path) if ROUTES.contains(&path) => {
                self.respond_error(token, 405, "method not allowed", keep);
            }
            _ => self.respond_error(token, 404, "no such route", keep),
        }
    }

    /// Validate and submit a classify batch; on success the connection
    /// parks (`Pending::Classify`) until every id completes.
    fn dispatch_classify(&mut self, token: u64, req: &Request, keep: bool) {
        let t0 = Instant::now();
        // span ids are minted at submit; backdate the gateway stages
        // (request accepted, body parsed) onto them afterwards
        let t_accept = self.inner.server.obs().trace.now_ns();
        let batch = match parse_classify_body(&self.inner, &req.body) {
            Ok(batch) => batch,
            Err(msg) => return self.respond_error(token, 400, &msg, keep),
        };
        let t_parsed = self.inner.server.obs().trace.now_ns();
        if self.inner.state() != RUNNING {
            return self.respond_error(token, 503, "gateway is draining", keep);
        }
        let k = batch.len();
        let bound = self.inner.tier.classify_bound();
        // a batch that can never fit the admission bound is a terminal
        // client error, not a retryable 429 (retrying would loop forever)
        if k > bound {
            let msg = format!("batch of {k} exceeds the admission bound {bound}");
            return self.respond_error(token, 400, &msg, keep);
        }
        let subs: Vec<Submission> =
            batch.into_iter().map(|tokens| Submission::Classify { tokens }).collect();
        match self.inner.tier.submit(subs) {
            Ok(ids) => {
                let trace = &self.inner.server.obs().trace;
                for &id in &ids {
                    trace.event_at(id, crate::obs::Stage::Accepted, t_accept);
                    trace.event_at(id, crate::obs::Stage::Parsed, t_parsed);
                    self.jobs.insert(id, token);
                }
                self.inner.active_requests.fetch_add(1, Ordering::SeqCst);
                let deadline = t0 + self.inner.cfg.request_timeout;
                if let Some(entry) = self.conns.get_mut(&token) {
                    entry.pending = Pending::Classify {
                        got: HashMap::with_capacity(ids.len()),
                        ids,
                        t0,
                        deadline,
                        keep,
                    };
                }
            }
            Err(SubmitError::Saturated) => {
                self.respond_error(token, 429, "serving queue is full", keep)
            }
            Err(SubmitError::Closed) => {
                self.respond_error(token, 503, "serving tier unavailable", keep)
            }
        }
    }

    /// Validate and submit one generate session; on success the stream
    /// head goes on the wire and the connection parks
    /// (`Pending::Generate`), chunks appending as the tier produces.
    fn dispatch_generate(&mut self, token: u64, req: &Request, keep: bool) {
        let t_accept = self.inner.server.obs().trace.now_ns();
        let (prompt, prefix, max_new, sampling) = match parse_generate_body(&self.inner, &req.body)
        {
            Ok(parsed) => parsed,
            Err(msg) => return self.respond_error(token, 400, &msg, keep),
        };
        let t_parsed = self.inner.server.obs().trace.now_ns();
        if self.inner.state() != RUNNING {
            return self.respond_error(token, 503, "gateway is draining", keep);
        }
        // paged preflight: a prefix session whose worst-case block
        // demand can't be reserved right now would only be refused by
        // the generate leader after queueing — answer 429 up front so
        // clients back off. Advisory only (the leader's reservation is
        // the authoritative check); a race just means a late refusal.
        if let Some(p) = &prefix {
            let demand =
                self.inner.server.paged_session_demand(p.len() + prompt.len() + max_new);
            if !self.inner.server.paged_pool().can_reserve(demand) {
                return self.respond_error(token, 429, "paged KV pool is full", keep);
            }
        }
        match self
            .inner
            .tier
            .submit(vec![Submission::Generate { prompt, prefix, max_new, sampling }])
        {
            Ok(ids) => {
                let id = ids[0];
                let trace = &self.inner.server.obs().trace;
                trace.event_at(id, crate::obs::Stage::Accepted, t_accept);
                trace.event_at(id, crate::obs::Stage::Parsed, t_parsed);
                self.inner.stats.streams_total.fetch_add(1, Ordering::Relaxed);
                self.inner.stats.record_status(200);
                self.jobs.insert(id, token);
                self.inner.active_requests.fetch_add(1, Ordering::SeqCst);
                let head =
                    http::render_stream_head(200, &[("Content-Type", "application/x-ndjson")]);
                let deadline = Instant::now() + self.inner.cfg.request_timeout;
                if let Some(entry) = self.conns.get_mut(&token) {
                    entry.conn.enqueue(&head);
                    entry.pending = Pending::Generate { id, deadline, keep };
                }
            }
            Err(SubmitError::Saturated) => {
                self.respond_error(token, 429, "all generate sessions are busy", keep)
            }
            Err(SubmitError::Closed) => {
                self.respond_error(token, 503, "serving tier unavailable", keep)
            }
        }
    }

    /// Drain the tier's completion queue and resume parked conns.
    fn drain_completions(&mut self) {
        let mut completions = mem::take(&mut self.completions);
        self.inner.tier.take_completions(&mut completions);
        for c in completions.drain(..) {
            match c {
                Completion::Classify { id, logits, latency } => {
                    self.finish_classify(id, logits, latency)
                }
                Completion::ClassifyFailed { id, fault } => self.finish_classify_failed(id, fault),
                Completion::Generate { id, tokens, done, fault } => {
                    self.stream_generate(id, tokens, done, fault)
                }
            }
        }
        self.completions = completions;
    }

    /// One classify id came back as a typed fault (retry budget spent
    /// on faulted replicas): the whole parked batch fails with a 500
    /// carrying the stable `replica_fault` code — a per-request error,
    /// distinct from `tier_timeout` (deadline) and never a tier crash.
    fn finish_classify_failed(&mut self, id: u64, fault: StreamFault) {
        let Some(&token) = self.jobs.get(&id) else { return };
        let keep = {
            let Some(entry) = self.conns.get_mut(&token) else {
                self.jobs.remove(&id);
                return;
            };
            match mem::replace(&mut entry.pending, Pending::None) {
                Pending::Classify { ids, keep, .. } => {
                    for id in ids {
                        self.jobs.remove(&id);
                    }
                    keep
                }
                other => {
                    entry.pending = other;
                    self.jobs.remove(&id);
                    return;
                }
            }
        };
        self.inner.active_requests.fetch_sub(1, Ordering::SeqCst);
        self.respond_error_coded(token, 500, fault.code, &fault.message, keep);
        self.advance_conn(token);
    }

    /// One classify id finished; when its whole batch has, render the
    /// response (ordered by submission ids, bit-exact f32 transport)
    /// and resume the connection.
    fn finish_classify(&mut self, id: u64, logits: Vec<f32>, latency: Duration) {
        let Some(&token) = self.jobs.get(&id) else { return };
        self.jobs.remove(&id);
        let ready = {
            let Some(entry) = self.conns.get_mut(&token) else { return };
            match &mut entry.pending {
                Pending::Classify { ids, got, .. } => {
                    got.insert(id, (logits, latency));
                    if got.len() == ids.len() {
                        match mem::replace(&mut entry.pending, Pending::None) {
                            Pending::Classify { ids, got, t0, keep, .. } => {
                                Some((ids, got, t0, keep))
                            }
                            _ => unreachable!("pending variant checked above"),
                        }
                    } else {
                        None
                    }
                }
                _ => None,
            }
        };
        let Some((ids, got, t0, keep)) = ready else { return };
        self.inner.active_requests.fetch_sub(1, Ordering::SeqCst);
        let mut body = String::from("{\"logits\":[");
        for (i, id) in ids.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            body.push_str(&json::f32_array(&got[id].0));
        }
        body.push_str("],\"latency_ms\":[");
        for (i, id) in ids.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            body.push_str(&format!("{:.3}", got[id].1.as_secs_f64() * 1e3));
        }
        body.push_str("]}");
        self.inner.record_classify_latency(t0.elapsed().as_secs_f64());
        self.respond_json(token, 200, &body, keep);
        self.advance_conn(token);
    }

    /// One generate slice arrived: append it to the stream (empty
    /// prefill slices stay off the wire), refresh the stall deadline,
    /// and on `done` finish the chunked framing and resume. A stream an
    /// unrecoverable replica fault cut short ends with an in-band error
    /// envelope line (`replica_fault`) instead of a token line — the
    /// HTTP status is already on the wire, so faults mid-stream travel
    /// in-band, mirroring the `tier_timeout` stall line.
    fn stream_generate(&mut self, id: u64, tokens: Vec<i32>, done: bool, fault: Option<StreamFault>) {
        let Some(&token) = self.jobs.get(&id) else { return };
        self.inner.stats.stream_tokens_total.fetch_add(tokens.len(), Ordering::Relaxed);
        {
            let Some(entry) = self.conns.get_mut(&token) else {
                self.jobs.remove(&id);
                return;
            };
            let Pending::Generate { deadline, keep, .. } = &mut entry.pending else { return };
            *deadline = Instant::now() + self.inner.cfg.request_timeout;
            let keep = *keep;
            if let Some(fault) = &fault {
                let line = format!(
                    "{{\"error\":{{\"code\":{},\"message\":{}}},\"done\":true}}\n",
                    Json::Str(fault.code.to_string()).encode(),
                    Json::Str(fault.message.clone()).encode()
                );
                entry.conn.enqueue(&http::render_chunk(line.as_bytes()));
            } else if !tokens.is_empty() || done {
                let line = format!(
                    "{{\"tokens\":{},\"done\":{}}}\n",
                    json::i32_array(&tokens),
                    done
                );
                entry.conn.enqueue(&http::render_chunk(line.as_bytes()));
            }
            if done {
                entry.conn.enqueue(&http::render_final_chunk());
                entry.pending = Pending::None;
                entry.conn.complete(keep);
            }
        }
        if done {
            self.jobs.remove(&id);
            self.inner.active_requests.fetch_sub(1, Ordering::SeqCst);
            self.advance_conn(token);
        } else {
            self.flush_and_update(token);
        }
    }

    /// Timer pass, once per tick: idle/slow-loris expiry, drain
    /// soft-closes, and request deadlines.
    fn sweep(&mut self) {
        let now = Instant::now();
        let draining = self.inner.state() == DRAINING;
        let idle_timeout = self.inner.cfg.idle_timeout;
        enum Action {
            Reap,
            SoftClose,
            ClassifyTimeout,
            GenerateTimeout,
        }
        let mut actions: Vec<(u64, Action)> = Vec::new();
        for (&token, entry) in &self.conns {
            match &entry.pending {
                Pending::None => {
                    if entry.conn.idle_expired(now, idle_timeout) {
                        actions.push((token, Action::Reap));
                    } else if draining
                        && entry.conn.buffered() == 0
                        && !entry.conn.wants_write()
                        && entry.conn.idle_expired(now, DRAIN_GRACE)
                    {
                        // during a drain idle sockets close early, but
                        // only after a grace window so a probe that
                        // just connected still gets its answer
                        actions.push((token, Action::SoftClose));
                    }
                }
                Pending::Classify { deadline, .. } if now >= *deadline => {
                    actions.push((token, Action::ClassifyTimeout));
                }
                Pending::Generate { deadline, .. } if now >= *deadline => {
                    actions.push((token, Action::GenerateTimeout));
                }
                _ => {}
            }
        }
        for (token, action) in actions {
            match action {
                Action::Reap => {
                    self.inner.stats.conns_reaped_total.fetch_add(1, Ordering::Relaxed);
                    self.close_conn(token);
                }
                Action::SoftClose => {
                    if let Some(entry) = self.conns.get_mut(&token) {
                        entry.conn.mark_closing();
                    }
                    self.flush_and_update(token);
                }
                Action::ClassifyTimeout => self.classify_timeout(token),
                Action::GenerateTimeout => self.generate_timeout(token),
            }
        }
    }

    /// The tier missed a classify deadline: unpark with a 500. A late
    /// completion for the abandoned ids is dropped at the jobs lookup.
    fn classify_timeout(&mut self, token: u64) {
        let keep = {
            let Some(entry) = self.conns.get_mut(&token) else { return };
            match mem::replace(&mut entry.pending, Pending::None) {
                Pending::Classify { ids, keep, .. } => {
                    for id in ids {
                        self.jobs.remove(&id);
                    }
                    keep
                }
                other => {
                    entry.pending = other;
                    return;
                }
            }
        };
        self.inner.active_requests.fetch_sub(1, Ordering::SeqCst);
        self.respond_error(token, 500, "timed out on the serving tier", keep);
        self.advance_conn(token);
    }

    /// The decode tier stalled mid-stream: emit the in-band envelope
    /// line, terminate the chunked framing cleanly, and close.
    fn generate_timeout(&mut self, token: u64) {
        {
            let Some(entry) = self.conns.get_mut(&token) else { return };
            match mem::replace(&mut entry.pending, Pending::None) {
                Pending::Generate { id, .. } => {
                    self.jobs.remove(&id);
                }
                other => {
                    entry.pending = other;
                    return;
                }
            }
            entry.conn.enqueue(&http::render_chunk(STREAM_STALL_LINE.as_bytes()));
            entry.conn.enqueue(&http::render_final_chunk());
            entry.conn.complete(false);
        }
        self.inner.active_requests.fetch_sub(1, Ordering::SeqCst);
        self.flush_and_update(token);
    }

    /// Drain completion: nothing parked on the tier and every
    /// out-buffer flushed → STOPPED (the caller breaks the loop).
    fn try_finish_drain(&mut self) -> bool {
        let busy = !self.inner.tier.idle()
            || self
                .conns
                .values()
                .any(|e| !matches!(e.pending, Pending::None) || e.conn.wants_write());
        if busy {
            return false;
        }
        self.inner.state.store(STOPPED, Ordering::SeqCst);
        true
    }

    /// Flush what the socket will take, then reconcile epoll interest
    /// with what the state machine wants; tear down finished conns.
    fn flush_and_update(&mut self, token: u64) {
        let mut dead = false;
        {
            let Some(entry) = self.conns.get_mut(&token) else { return };
            // injected socket-write fault (chaos): behave exactly like
            // a peer reset mid-write — the conn is torn down, its jobs
            // unrouted, and the loop keeps serving everyone else
            let injected = entry.conn.wants_write()
                && self
                    .inner
                    .server
                    .fault_injector()
                    .is_some_and(|f| f.trip(FaultSite::GatewayWrite));
            if injected
                || (entry.conn.wants_write() && entry.conn.on_writable(&mut entry.stream).is_err())
            {
                dead = true;
            }
            if !dead {
                if entry.conn.done() {
                    dead = true;
                } else {
                    let want = Interest {
                        read: entry.conn.wants_read(),
                        write: entry.conn.wants_write(),
                    };
                    if !entry.registered {
                        if want != Interest::NONE {
                            if self
                                .poller
                                .register(entry.stream.as_raw_fd(), token, want)
                                .is_ok()
                            {
                                entry.registered = true;
                                entry.interest = want;
                            } else {
                                dead = true;
                            }
                        }
                    } else if want != entry.interest {
                        if self.poller.modify(entry.stream.as_raw_fd(), token, want).is_ok() {
                            entry.interest = want;
                        } else {
                            dead = true;
                        }
                    }
                }
            }
        }
        if dead {
            self.close_conn(token);
        }
    }

    /// Drop a connection: out of the epoll set, abandoned jobs
    /// unrouted, gauges updated, and the listener resumed if the
    /// `max_conns` cap had paused it.
    fn close_conn(&mut self, token: u64) {
        let Some(entry) = self.conns.remove(&token) else { return };
        if entry.registered {
            let _ = self.poller.deregister(entry.stream.as_raw_fd());
        }
        match entry.pending {
            Pending::None => {}
            Pending::Classify { ids, .. } => {
                for id in ids {
                    self.jobs.remove(&id);
                }
                self.inner.active_requests.fetch_sub(1, Ordering::SeqCst);
            }
            Pending::Generate { id, .. } => {
                self.jobs.remove(&id);
                self.inner.active_requests.fetch_sub(1, Ordering::SeqCst);
            }
        }
        self.inner.stats.open_connections.fetch_sub(1, Ordering::Relaxed);
        if self.listener_paused && self.conns.len() < self.inner.cfg.max_conns {
            if self
                .poller
                .modify(self.listener.as_raw_fd(), TOKEN_LISTENER, Interest::READ)
                .is_ok()
            {
                self.listener_paused = false;
            }
        }
    }

    // --- response helpers -------------------------------------------

    fn respond(
        &mut self,
        token: u64,
        code: u16,
        headers: &[(&str, &str)],
        body: &[u8],
        keep: bool,
    ) {
        self.inner.stats.record_status(code);
        let frame = http::render_response(code, headers, body);
        if let Some(entry) = self.conns.get_mut(&token) {
            entry.conn.enqueue(&frame);
            entry.conn.complete(keep);
        }
    }

    fn respond_json(&mut self, token: u64, code: u16, body: &str, keep: bool) {
        self.respond(token, code, &[("Content-Type", "application/json")], body.as_bytes(), keep);
    }

    /// Answer with the unified error envelope; 429s carry both the
    /// `Retry-After` header and the envelope's `retry_after_ms`.
    fn respond_error(&mut self, token: u64, code: u16, msg: &str, keep: bool) {
        self.respond_error_coded(token, code, error_code(code), msg, keep);
    }

    /// [`respond_error`](Self::respond_error) with an explicit envelope
    /// code (see [`error_body_coded`]).
    fn respond_error_coded(&mut self, token: u64, status: u16, code: &str, msg: &str, keep: bool) {
        let retry_ms = self.inner.cfg.retry_after_ms;
        let body = error_body_coded(status, code, msg, retry_ms);
        if status == 429 {
            // header granularity is whole seconds — round up so a
            // sub-second hint never becomes "retry immediately"
            let retry_after = ((retry_ms + 999) / 1000).to_string();
            self.respond(
                token,
                status,
                &[("Retry-After", &retry_after), ("Content-Type", "application/json")],
                body.as_bytes(),
                keep,
            );
        } else {
            self.respond_json(token, status, &body, keep);
        }
    }
}

// ---------------------------------------------------------------------
// route bodies and validation
// ---------------------------------------------------------------------

fn healthz_body(inner: &Inner) -> (u16, String) {
    let draining = inner.state() != RUNNING;
    let body = format!(
        "{{\"status\":\"{}\",\"seq_len\":{},\"vocab\":{},\"n_classes\":{},\"replicas\":{}}}",
        if draining { "draining" } else { "ok" },
        inner.server.seq_len(),
        inner.server.vocab(),
        inner.server.n_classes(),
        inner.cfg.replicas
    );
    (if draining { 503 } else { 200 }, body)
}

/// Render the Prometheus exposition: tier rows straight from
/// [`Server::live_snapshot`] (the same [`MetricRow`]s the CLI prints),
/// then gateway-level counters, per-shard plan-cache stats, and the
/// per-lane latency histograms (`_bucket`/`_sum`/`_count`). Every
/// family carries `# HELP`/`# TYPE` through [`PromWriter`].
fn metrics_body(inner: &Inner) -> String {
    let mut w = PromWriter::new("esact_");
    for row in inner.server.live_snapshot().rows() {
        w.scalar(row.name, &row.to_string(), help_for(row.name));
    }
    let s = &inner.stats;
    let http_lat = inner.classify_latencies.lock().unwrap().percentiles();
    let gw_rows = [
        MetricRow::of("gateway_state", inner.state() as f64),
        MetricRow::of("gateway_uptime_seconds", inner.started.elapsed().as_secs_f64()),
        MetricRow::of(
            "gateway_connections_total",
            s.connections_total.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of(
            "gateway_open_connections",
            s.open_connections.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of(
            "gateway_conns_reaped_total",
            s.conns_reaped_total.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of(
            "gateway_http_requests_total",
            s.http_requests_total.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of(
            "gateway_responses_2xx_total",
            s.responses_2xx.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of(
            "gateway_responses_4xx_total",
            s.responses_4xx.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of(
            "gateway_responses_5xx_total",
            s.responses_5xx.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of("gateway_shed_total", s.shed_total.load(Ordering::Relaxed) as f64),
        MetricRow::of(
            "gateway_bad_requests_total",
            s.bad_requests_total.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of("gateway_streams_total", s.streams_total.load(Ordering::Relaxed) as f64),
        MetricRow::of(
            "gateway_stream_tokens_total",
            s.stream_tokens_total.load(Ordering::Relaxed) as f64,
        ),
        MetricRow::of("gateway_classify_in_flight", inner.tier.classify_in_flight() as f64),
        MetricRow::of("gateway_generate_in_flight", inner.tier.generate_in_flight() as f64),
        MetricRow::of(
            "gateway_active_requests",
            inner.active_requests.load(Ordering::SeqCst) as f64,
        ),
        MetricRow::of("gateway_classify_http_p50_seconds", http_lat.0),
        MetricRow::of("gateway_classify_http_p99_seconds", http_lat.1),
    ];
    for row in gw_rows {
        w.scalar(row.name, &row.to_string(), help_for(row.name));
    }
    for row in paged_rows(&inner.server.paged_stats()) {
        w.scalar(row.name, &row.to_string(), help_for(row.name));
    }
    for (i, shard) in inner.server.plan_cache_shard_stats().iter().enumerate() {
        let rows = [
            MetricRow::labeled("plan_cache_shard_entries", "shard", i, shard.entries as f64),
            MetricRow::labeled("plan_cache_shard_hits_total", "shard", i, shard.hits as f64),
            MetricRow::labeled("plan_cache_shard_misses_total", "shard", i, shard.misses as f64),
            MetricRow::labeled(
                "plan_cache_shard_step_entries",
                "shard",
                i,
                shard.step_entries as f64,
            ),
        ];
        for row in rows {
            w.scalar(row.name, &row.to_string(), help_for(row.name));
        }
    }
    let obs = inner.server.obs();
    for (lane, hists) in [("classify", &obs.classify), ("generate", &obs.generate)] {
        let families = [
            ("latency", &hists.total),
            ("queue_wait", &hists.queue_wait),
            ("execute", &hists.execute),
            ("ttft", &hists.ttft),
        ];
        for (stem, h) in families {
            let name = format!("{lane}_{stem}_seconds");
            w.histogram(&name, &h.snapshot(), help_for(&name));
        }
    }
    let completed = obs.trace.completed();
    w.scalar(
        "trace_spans_completed_total",
        &format!("trace_spans_completed_total {completed}"),
        help_for("trace_spans_completed_total"),
    );
    w.into_string()
}

/// Render `GET /debug/trace`: the last `n` (default 32, cap 256)
/// completed spans newest-first, plus the all-time completed count.
fn trace_body(inner: &Inner, req: &Request) -> String {
    let n = req
        .query_param("n")
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(32)
        .min(256);
    let trace = &inner.server.obs().trace;
    let spans = trace.recent(n);
    let mut body = String::from("{\"spans\":[");
    for (i, span) in spans.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&span.to_json());
    }
    body.push_str("],\"completed\":");
    body.push_str(&trace.completed().to_string());
    body.push('}');
    body
}

/// Validate and extract the classify batch: `{"tokens": [[...], ...]}`
/// (a single flat `[...]` is accepted as a batch of one).
fn parse_classify_body(inner: &Inner, body: &[u8]) -> Result<Vec<Vec<i32>>, String> {
    let text =
        std::str::from_utf8(body).map_err(|_| "body is not valid UTF-8".to_string())?;
    let doc = Json::parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let tokens = doc.get("tokens").ok_or("missing \"tokens\" field")?;
    let arr = tokens.as_arr().ok_or("\"tokens\" must be an array")?;
    let nested = arr.first().is_some_and(|x| x.as_arr().is_some());
    let seqs: Vec<&Json> = if nested { arr.iter().collect() } else { vec![tokens] };
    if seqs.is_empty() {
        return Err("empty batch".to_string());
    }
    if seqs.len() > MAX_BATCH_PER_REQUEST {
        return Err(format!("batch larger than {MAX_BATCH_PER_REQUEST}"));
    }
    let (l, vocab) = (inner.server.seq_len(), inner.server.vocab() as i32);
    seqs.iter()
        .map(|s| {
            let toks = json::to_i32_vec(s).ok_or("tokens must be an array of integers")?;
            if toks.len() != l {
                return Err(format!("sequence length {} != compiled L {l}", toks.len()));
            }
            if let Some(bad) = toks.iter().find(|&&t| t < 0 || t >= vocab) {
                return Err(format!("token id {bad} outside vocab 0..{vocab}"));
            }
            Ok(toks)
        })
        .collect()
}

type GenerateParams = (Vec<i32>, Option<Vec<i32>>, usize, Sampling);

/// Validate `/v1/generate` bodies:
/// `{"prompt": [...], "prefix": [...]?, "max_new": n, "top_k": k?,
/// "temperature": t?, "seed": s?}`. With `"prefix"`, the prompt is the
/// tail after the shared prefix and the session decodes through the
/// server's paged KV pool (prefix-trie sharing).
fn parse_generate_body(inner: &Inner, body: &[u8]) -> Result<GenerateParams, String> {
    let text =
        std::str::from_utf8(body).map_err(|_| "body is not valid UTF-8".to_string())?;
    let doc = Json::parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let prompt = json::to_i32_vec(doc.get("prompt").ok_or("missing \"prompt\" field")?)
        .ok_or("\"prompt\" must be an array of integers")?;
    if prompt.is_empty() {
        return Err("empty prompt".to_string());
    }
    if prompt.len() > MAX_NEW_CAP {
        return Err(format!("prompt longer than {MAX_NEW_CAP}"));
    }
    let vocab = inner.server.vocab() as i32;
    if let Some(bad) = prompt.iter().find(|&&t| t < 0 || t >= vocab) {
        return Err(format!("token id {bad} outside vocab 0..{vocab}"));
    }
    let prefix = match doc.get("prefix") {
        None => None,
        Some(v) => {
            let p = json::to_i32_vec(v).ok_or("\"prefix\" must be an array of integers")?;
            if p.len() + prompt.len() > MAX_NEW_CAP {
                return Err(format!("prefix + prompt longer than {MAX_NEW_CAP}"));
            }
            if let Some(bad) = p.iter().find(|&&t| t < 0 || t >= vocab) {
                return Err(format!("token id {bad} outside vocab 0..{vocab}"));
            }
            // an empty prefix array is a no-op, same as omitting it
            (!p.is_empty()).then_some(p)
        }
    };
    let max_new = match doc.get("max_new") {
        None => 16,
        Some(v) => v.as_usize().ok_or("\"max_new\" must be a non-negative integer")?,
    };
    if max_new > MAX_NEW_CAP {
        return Err(format!("max_new larger than {MAX_NEW_CAP}"));
    }
    let sampling = match doc.get("top_k") {
        None => Sampling::Greedy,
        Some(v) => {
            let k = v.as_usize().filter(|&k| k >= 1).ok_or("\"top_k\" must be >= 1")?;
            let temperature = match doc.get("temperature") {
                None => 1.0,
                Some(t) => t.as_f64().filter(|t| *t > 0.0).ok_or("bad \"temperature\"")? as f32,
            };
            let seed = match doc.get("seed") {
                None => 0,
                Some(s) => s.as_i64().filter(|s| *s >= 0).ok_or("bad \"seed\"")? as u64,
            };
            Sampling::TopK { k, temperature, seed }
        }
    };
    Ok((prompt, prefix, max_new, sampling))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SplsConfig;
    use crate::net::client::{
        classify_body, generate_body, generate_body_with_prefix, metric_value, HttpClient,
    };
    use crate::util::rng::Xoshiro256pp;
    use std::io::{Read, Write};
    use std::path::Path;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn start_gateway(cfg: GatewayConfig) -> (Gateway, String) {
        let srv =
            Arc::new(Server::new(&artifacts_dir(), cfg.mode, SplsConfig::default()).unwrap());
        let gw = Gateway::start(srv, cfg).unwrap();
        let addr = gw.local_addr().to_string();
        (gw, addr)
    }

    fn seqs(n: usize, l: usize) -> Vec<Vec<i32>> {
        let mut rng = Xoshiro256pp::new(5);
        (0..n).map(|_| crate::model::synth::gen_example(&mut rng, l).0).collect()
    }

    /// Read one full response off a raw socket (status line + head +
    /// best-effort body) as text.
    fn read_response_text(s: &mut TcpStream) -> String {
        let _ = s.set_read_timeout(Some(Duration::from_secs(5)));
        let mut buf = Vec::new();
        let mut tmp = [0u8; 2048];
        while !buf.windows(4).any(|w| w == b"\r\n\r\n") {
            match s.read(&mut tmp) {
                Ok(0) => break,
                Ok(n) => buf.extend_from_slice(&tmp[..n]),
                Err(_) => break,
            }
        }
        String::from_utf8_lossy(&buf).to_string()
    }

    /// Read until EOF or timeout — for responses that close the conn,
    /// this captures the complete body.
    fn read_all_text(s: &mut TcpStream) -> String {
        let _ = s.set_read_timeout(Some(Duration::from_millis(500)));
        let mut buf = Vec::new();
        let mut tmp = [0u8; 4096];
        loop {
            match s.read(&mut tmp) {
                Ok(0) => break,
                Ok(n) => buf.extend_from_slice(&tmp[..n]),
                Err(_) => break,
            }
        }
        String::from_utf8_lossy(&buf).to_string()
    }

    fn default_cfg() -> GatewayConfig {
        GatewayConfig::builder().build().unwrap()
    }

    #[test]
    fn builder_validates_every_bound() {
        assert!(GatewayConfig::builder().max_conns(0).build().is_err());
        assert!(GatewayConfig::builder().replicas(0).build().is_err());
        assert!(GatewayConfig::builder()
            .policy(BatchPolicy { max_queue: 0, ..Default::default() })
            .build()
            .is_err());
        assert!(GatewayConfig::builder().steps_per_slice(0).build().is_err());
        assert!(GatewayConfig::builder().max_sessions(0).build().is_err());
        assert!(GatewayConfig::builder().max_body(0).build().is_err());
        assert!(GatewayConfig::builder().max_events(0).build().is_err());
        assert!(GatewayConfig::builder().request_timeout(Duration::ZERO).build().is_err());
        assert!(GatewayConfig::builder().idle_timeout(Duration::ZERO).build().is_err());
        assert!(GatewayConfig::builder().retry_after_ms(0).build().is_err());
        // trace_sample 0 is valid: it means tracing off, not a wedge
        assert!(GatewayConfig::builder().trace_sample(0).build().is_ok());
        let cfg = GatewayConfig::builder()
            .addr("127.0.0.1:0")
            .max_conns(64)
            .idle_timeout(Duration::from_millis(500))
            .build()
            .unwrap();
        assert_eq!(cfg.max_conns, 64);
        assert_eq!(cfg.idle_timeout, Duration::from_millis(500));
        // untouched knobs keep the documented defaults
        assert_eq!(cfg.max_events, 256);
        assert_eq!(cfg.request_timeout, Duration::from_secs(30));
        assert_eq!(cfg.retry_after_ms, 1000);
        assert_eq!(cfg.trace_sample, 1);
    }

    #[test]
    fn error_envelope_has_stable_codes_and_retry_hint() {
        let doc = Json::parse(&error_body(429, "serving queue is full")).unwrap();
        let err = doc.get("error").unwrap();
        assert_eq!(err.get("code").unwrap().as_str(), Some("saturated"));
        assert_eq!(err.get("message").unwrap().as_str(), Some("serving queue is full"));
        assert_eq!(err.get("retry_after_ms").unwrap().as_usize(), Some(1000));
        let doc = Json::parse(&error_body(404, "no such route")).unwrap();
        let err = doc.get("error").unwrap();
        assert_eq!(err.get("code").unwrap().as_str(), Some("not_found"));
        assert!(err.get("retry_after_ms").is_none(), "only 429 carries the hint");
        // the hint tracks the configured value, not a baked-in constant
        let doc = Json::parse(&error_body_coded(429, "saturated", "busy", 250)).unwrap();
        assert_eq!(
            doc.get("error").unwrap().get("retry_after_ms").unwrap().as_usize(),
            Some(250)
        );
        // messages with quotes stay valid JSON
        let doc = Json::parse(&error_body(400, "missing \"tokens\" field")).unwrap();
        assert_eq!(
            doc.get("error").unwrap().get("message").unwrap().as_str(),
            Some("missing \"tokens\" field")
        );
    }

    #[test]
    fn healthz_metrics_and_unknown_routes_over_one_keepalive_conn() {
        let (gw, addr) = start_gateway(default_cfg());
        let mut c = HttpClient::connect(&addr).unwrap();
        let h = c.get("/healthz").unwrap();
        assert_eq!(h.status, 200);
        let doc = h.json().unwrap();
        assert_eq!(doc.get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(doc.get("seq_len").unwrap().as_usize(), Some(64));
        assert_eq!(doc.get("vocab").unwrap().as_usize(), Some(64));
        // the same connection serves further exchanges (keep-alive)
        let m = c.get("/metrics").unwrap();
        assert_eq!(m.status, 200);
        let text = String::from_utf8(m.body).unwrap();
        for needle in [
            "esact_serve_requests_total",
            "esact_generate_tokens_total",
            "esact_plan_cache_hit_rate",
            "esact_gateway_http_requests_total",
            "esact_gateway_open_connections",
            "esact_replica_busy_seconds",
            "esact_plan_cache_shard_entries{shard=\"0\"}",
        ] {
            assert!(text.contains(needle), "metrics missing {needle}:\n{text}");
        }
        let nf = c.get("/nope").unwrap();
        assert_eq!(nf.status, 404);
        let err = nf.json().unwrap();
        assert_eq!(
            err.get("error").unwrap().get("code").unwrap().as_str(),
            Some("not_found")
        );
        let mna = c.post_json("/healthz", "{}").unwrap();
        assert_eq!(mna.status, 405);
        assert_eq!(
            mna.json().unwrap().get("error").unwrap().get("code").unwrap().as_str(),
            Some("method_not_allowed")
        );
        gw.shutdown().unwrap();
    }

    #[test]
    fn classify_validates_input_before_the_executor_can_panic() {
        let (gw, addr) = start_gateway(default_cfg());
        let mut c = HttpClient::connect(&addr).unwrap();
        let pool = seqs(2, 64);
        let body = classify_body(&[&pool[0][..], &pool[1][..]]);
        let ok = c.post_json("/v1/classify", &body).unwrap();
        assert_eq!(ok.status, 200);
        let doc = ok.json().unwrap();
        let logits = doc.get("logits").unwrap().as_arr().unwrap();
        assert_eq!(logits.len(), 2);
        assert!(logits.iter().all(|row| row.as_arr().unwrap().len() == 16));
        let bad_bodies: Vec<String> = vec![
            "{not json".to_string(),
            "{\"tokens\": 3}".to_string(),
            "{}".to_string(),
            "{\"tokens\": []}".to_string(),
            "{\"tokens\": [[1.5, 2]]}".to_string(),
            classify_body(&[&vec![0i32; 10][..]]),    // wrong L
            classify_body(&[&vec![9999i32; 64][..]]), // out of vocab
        ];
        for bad in &bad_bodies {
            let r = c.post_json("/v1/classify", bad).unwrap();
            assert_eq!(r.status, 400, "{bad:?}");
            // every 400 carries the envelope with a stable code
            let err = r.json().unwrap();
            assert_eq!(
                err.get("error").unwrap().get("code").unwrap().as_str(),
                Some("bad_request"),
                "{bad:?}"
            );
        }
        // the gateway is still healthy after all that abuse
        assert_eq!(c.get("/healthz").unwrap().status, 200);
        gw.shutdown().unwrap();
    }

    #[test]
    fn raw_socket_abuse_gets_clean_http_errors() {
        let (gw, addr) = start_gateway(default_cfg());
        // invalid UTF-8 body → 400, connection stays usable
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"POST /v1/classify HTTP/1.1\r\nContent-Length: 2\r\n\r\n\xff\xfe")
            .unwrap();
        let text = read_response_text(&mut s);
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
        // garbage request line → 400 envelope and close
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"GARBAGE\r\n\r\n").unwrap();
        let text = read_all_text(&mut s);
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
        assert!(text.contains("\"code\":\"bad_request\""), "{text}");
        // oversized declared body → 413 envelope
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"POST /v1/classify HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n")
            .unwrap();
        let text = read_all_text(&mut s);
        assert!(text.starts_with("HTTP/1.1 413"), "{text}");
        assert!(text.contains("\"code\":\"body_too_large\""), "{text}");
        // unsupported HTTP version → 505 envelope
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"GET /healthz HTTP/2.0\r\n\r\n").unwrap();
        let text = read_all_text(&mut s);
        assert!(text.starts_with("HTTP/1.1 505"), "{text}");
        assert!(text.contains("\"code\":\"http_version\""), "{text}");
        // two pipelined requests in one segment → two responses in order
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"GET /healthz HTTP/1.1\r\n\r\nGET /nope HTTP/1.1\r\n\r\n").unwrap();
        std::thread::sleep(Duration::from_millis(300));
        let mut buf = Vec::new();
        let _ = s.set_read_timeout(Some(Duration::from_millis(500)));
        let mut tmp = [0u8; 4096];
        while let Ok(n) = s.read(&mut tmp) {
            if n == 0 {
                break;
            }
            buf.extend_from_slice(&tmp[..n]);
        }
        let text = String::from_utf8_lossy(&buf).to_string();
        let first = text.find("HTTP/1.1 200").expect("healthz response");
        let second = text.find("HTTP/1.1 404").expect("pipelined 404 response");
        assert!(first < second, "pipelined responses must come back in order");
        gw.shutdown().unwrap();
    }

    #[test]
    fn saturation_sheds_with_429_retry_after_and_counts_it() {
        use std::sync::atomic::AtomicUsize;
        // admission bound 1: concurrent posts must overlap and shed
        let cfg = GatewayConfig::builder()
            .policy(BatchPolicy { max_queue: 1, ..Default::default() })
            .max_conns(12)
            .build()
            .unwrap();
        let (gw, addr) = start_gateway(cfg);
        let pool = Arc::new(seqs(4, 64));
        let ok = Arc::new(AtomicUsize::new(0));
        let shed = Arc::new(AtomicUsize::new(0));
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let (pool, ok, shed) = (Arc::clone(&pool), Arc::clone(&ok), Arc::clone(&shed));
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = HttpClient::connect(&addr).unwrap();
                    for i in 0..4 {
                        let body = classify_body(&[&pool[i % pool.len()][..]]);
                        let r = c.post_json("/v1/classify", &body).unwrap();
                        match r.status {
                            200 => {
                                ok.fetch_add(1, Ordering::Relaxed);
                            }
                            429 => {
                                assert_eq!(
                                    r.header("retry-after"),
                                    Some("1"),
                                    "429 must carry Retry-After"
                                );
                                let err = r.json().unwrap();
                                let env = err.get("error").unwrap();
                                assert_eq!(env.get("code").unwrap().as_str(), Some("saturated"));
                                assert_eq!(
                                    env.get("retry_after_ms").unwrap().as_usize(),
                                    Some(1000)
                                );
                                shed.fetch_add(1, Ordering::Relaxed);
                            }
                            other => panic!("unexpected status {other}"),
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let (ok, shed) = (ok.load(Ordering::Relaxed), shed.load(Ordering::Relaxed));
        assert_eq!(ok + shed, 32, "every post must be answered");
        assert!(ok >= 1, "the first admit must always succeed");
        assert!(shed >= 1, "8 racing connections over bound 1 must shed");
        // /metrics reports the same shed count
        let mut c = HttpClient::connect(&addr).unwrap();
        let text = String::from_utf8(c.get("/metrics").unwrap().body).unwrap();
        let line = text
            .lines()
            .find(|l| l.starts_with("esact_gateway_shed_total"))
            .expect("shed metric");
        let value: f64 = line.split_whitespace().last().unwrap().parse().unwrap();
        assert_eq!(value as usize, shed, "metrics and HTTP answers must agree");
        gw.shutdown().unwrap();
    }

    #[test]
    fn hundreds_of_idle_connections_churn_without_starving_requests() {
        let cfg = GatewayConfig::builder().max_conns(512).build().unwrap();
        let (gw, addr) = start_gateway(cfg);
        let mut idle: Vec<TcpStream> =
            (0..128).map(|_| TcpStream::connect(&addr).unwrap()).collect();
        // requests still flow while the idle herd sits connected
        let mut c = HttpClient::connect(&addr).unwrap();
        assert_eq!(c.get("/healthz").unwrap().status, 200);
        // churn: drop half, reconnect as many
        for s in idle.drain(..64) {
            drop(s);
        }
        for _ in 0..64 {
            idle.push(TcpStream::connect(&addr).unwrap());
        }
        assert_eq!(c.get("/healthz").unwrap().status, 200);
        // an arbitrary idle socket is still usable after the churn
        let s = idle.last_mut().unwrap();
        s.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        let text = read_response_text(s);
        assert!(text.starts_with("HTTP/1.1 200"), "{text}");
        // the gauge sees the herd (128 idle + the HttpClient)
        let text = String::from_utf8(c.get("/metrics").unwrap().body).unwrap();
        let line = text
            .lines()
            .find(|l| l.starts_with("esact_gateway_open_connections"))
            .expect("open_connections gauge");
        let value: f64 = line.split_whitespace().last().unwrap().parse().unwrap();
        assert!(value >= 129.0, "open_connections gauge too low: {value}");
        drop(idle);
        gw.shutdown().unwrap();
    }

    #[test]
    fn slow_loris_connections_are_reaped_and_counted() {
        let cfg = GatewayConfig::builder()
            .idle_timeout(Duration::from_millis(300))
            .build()
            .unwrap();
        let (gw, addr) = start_gateway(cfg);
        let mut lorises: Vec<TcpStream> = (0..8)
            .map(|_| {
                let mut s = TcpStream::connect(&addr).unwrap();
                // a partial request head that never completes
                s.write_all(b"POST /v1/classify HT").unwrap();
                s
            })
            .collect();
        // trickle another byte into half of them: idle time counts
        // from the last *completed* request, so it doesn't help
        std::thread::sleep(Duration::from_millis(150));
        for s in lorises.iter_mut().take(4) {
            let _ = s.write_all(b"T");
        }
        let mut c = HttpClient::connect(&addr).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let text = String::from_utf8(c.get("/metrics").unwrap().body).unwrap();
            let reaped = text
                .lines()
                .find(|l| l.starts_with("esact_gateway_conns_reaped_total"))
                .and_then(|l| l.split_whitespace().last())
                .and_then(|v| v.parse::<f64>().ok())
                .unwrap_or(0.0);
            if reaped >= 8.0 {
                break;
            }
            assert!(Instant::now() < deadline, "lorises not reaped, count {reaped}");
            std::thread::sleep(Duration::from_millis(50));
        }
        // the gateway is healthy throughout
        assert_eq!(c.get("/healthz").unwrap().status, 200);
        drop(lorises);
        gw.shutdown().unwrap();
    }

    #[test]
    fn metrics_export_step_cache_and_paged_pool_rows() {
        // satellite invariant: every decode-step plan-cache counter and
        // every paged-pool counter is scrapeable end-to-end, not just
        // present in internal structs
        let (gw, addr) = start_gateway(default_cfg());
        let mut c = HttpClient::connect(&addr).unwrap();
        let text = String::from_utf8(c.get("/metrics").unwrap().body).unwrap();
        for needle in [
            "esact_plan_cache_step_hits_total",
            "esact_plan_cache_step_misses_total",
            "esact_plan_cache_step_hit_rate",
            "esact_plan_cache_step_entries",
            "esact_plan_cache_step_evictions_total",
            "esact_paged_blocks_in_use",
            "esact_paged_blocks_peak",
            "esact_paged_blocks_capacity",
            "esact_paged_blocks_allocated_total",
            "esact_paged_cow_copies_total",
            "esact_paged_prefix_hits_total",
            "esact_paged_prefix_misses_total",
            "esact_paged_prefix_hit_rate",
            "esact_paged_shared_tokens_total",
        ] {
            assert!(text.contains(needle), "metrics missing {needle}:\n{text}");
        }
        // the capacity gauge reflects the server's configured pool
        let cap = metric_value(&mut c, "esact_paged_blocks_capacity").unwrap().unwrap();
        assert_eq!(cap as usize, crate::coordinator::DEFAULT_POOL_BLOCKS);
        gw.shutdown().unwrap();
    }

    #[test]
    fn generate_with_prefix_matches_concatenated_prompt_and_shares_blocks() {
        let (gw, addr) = start_gateway(default_cfg());
        let mut c = HttpClient::connect(&addr).unwrap();
        let prompt = &seqs(1, 64)[0][..16];
        let (prefix, tail) = prompt.split_at(12);
        let max_new = 8;
        // reference: the whole prompt as one private session
        let want = c
            .generate_stream(&generate_body(prompt, max_new, None))
            .unwrap()
            .collect()
            .unwrap()
            .tokens;
        assert_eq!(want.len(), max_new);
        // the same prompt split as prefix + tail must stream the same
        // tokens — the paged path is bit-identical, and the first
        // session publishes the prefix to the pool's trie
        let split = c
            .generate_stream(&generate_body_with_prefix(prefix, tail, max_new, None))
            .unwrap()
            .collect()
            .unwrap()
            .tokens;
        assert_eq!(split, want, "declared prefix must not change the stream");
        // a replayed split session attaches to the published blocks
        let replay = c
            .generate_stream(&generate_body_with_prefix(prefix, tail, max_new, None))
            .unwrap()
            .collect()
            .unwrap()
            .tokens;
        assert_eq!(replay, want);
        let hits = metric_value(&mut c, "esact_paged_prefix_hits_total").unwrap().unwrap();
        assert!(hits >= 1.0, "replayed prefix must hit the trie: {hits}");
        let shared =
            metric_value(&mut c, "esact_paged_shared_tokens_total").unwrap().unwrap();
        assert!(shared >= prefix.len() as f64, "attach must skip prefix tokens: {shared}");
        // malformed prefixes are refused before they can reach a session
        for bad in [
            "{\"prompt\":[1,2],\"prefix\":3}".to_string(),
            "{\"prompt\":[1,2],\"prefix\":[9999]}".to_string(),
        ] {
            let r = c.post_json("/v1/generate", &bad).unwrap();
            assert_eq!(r.status, 400, "{bad}");
        }
        gw.shutdown().unwrap();
    }

    #[test]
    fn generate_preflight_refuses_sessions_the_paged_pool_cannot_hold() {
        // a 16-block pool: worst-case demand is 8·(⌈total/8⌉+1) blocks
        // on the 2-layer × 4-head tiny model, so only prefix sessions
        // totalling ≤ 8 tokens fit
        let srv = Arc::new(
            Server::with_pool_blocks(&artifacts_dir(), Mode::Dense, SplsConfig::default(), 16)
                .unwrap(),
        );
        let gw = Gateway::start(srv, default_cfg()).unwrap();
        let addr = gw.local_addr().to_string();
        let mut c = HttpClient::connect(&addr).unwrap();
        let prompt = &seqs(1, 64)[0][..16];
        // 12-token prefix + 4 tail + 8 new = 24 total → demand 32 > 16
        let r = c
            .post_json(
                "/v1/generate",
                &generate_body_with_prefix(&prompt[..12], &prompt[12..16], 8, None),
            )
            .unwrap();
        let body = String::from_utf8_lossy(&r.body).to_string();
        assert_eq!(r.status, 429, "{body}");
        assert!(body.contains("\"saturated\""), "{body}");
        assert!(body.contains("paged KV pool is full"), "{body}");
        // 4-token prefix + 2 tail + 2 new = 8 total → demand 16, fits
        let small = c
            .generate_stream(&generate_body_with_prefix(&prompt[..4], &prompt[4..6], 2, None))
            .unwrap()
            .collect()
            .unwrap()
            .tokens;
        assert_eq!(small.len(), 2, "a session the pool can hold still streams");
        gw.shutdown().unwrap();
    }

    #[test]
    fn admin_shutdown_drains_and_closes_the_listener() {
        let (gw, addr) = start_gateway(default_cfg());
        let mut c = HttpClient::connect(&addr).unwrap();
        assert_eq!(c.post_json("/admin/shutdown", "").unwrap().status, 200);
        let report = gw.join().unwrap();
        assert_eq!(report.classify.metrics.requests, 0);
        assert_eq!(report.generate.metrics.sessions, 0);
        assert!(report.http_requests >= 1);
        // the listener is gone: fresh connections must start failing
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if TcpStream::connect(&addr).is_err() {
                break;
            }
            assert!(Instant::now() < deadline, "listener still accepting after drain");
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    #[test]
    fn configured_retry_after_reaches_envelope_and_header() {
        // the paged-pool preflight 429 is deterministic (no racing
        // needed): a session the 16-block pool cannot hold is refused
        let srv = Arc::new(
            Server::with_pool_blocks(&artifacts_dir(), Mode::Dense, SplsConfig::default(), 16)
                .unwrap(),
        );
        let cfg = GatewayConfig::builder().retry_after_ms(2500).build().unwrap();
        let gw = Gateway::start(srv, cfg).unwrap();
        let addr = gw.local_addr().to_string();
        let mut c = HttpClient::connect(&addr).unwrap();
        let prompt = &seqs(1, 64)[0][..16];
        let r = c
            .post_json(
                "/v1/generate",
                &generate_body_with_prefix(&prompt[..12], &prompt[12..16], 8, None),
            )
            .unwrap();
        assert_eq!(r.status, 429);
        // header rounds 2500 ms up to whole seconds
        assert_eq!(r.header("retry-after"), Some("3"));
        let err = r.json().unwrap();
        assert_eq!(
            err.get("error").unwrap().get("retry_after_ms").unwrap().as_usize(),
            Some(2500)
        );
        gw.shutdown().unwrap();
    }

    #[test]
    fn debug_trace_and_prometheus_histograms_round_trip() {
        use crate::obs::prom;
        let (gw, addr) = start_gateway(default_cfg());
        let mut c = HttpClient::connect(&addr).unwrap();
        let pool = seqs(2, 64);
        for s in &pool {
            assert_eq!(c.post_json("/v1/classify", &classify_body(&[&s[..]])).unwrap().status, 200);
        }
        let tokens = c
            .generate_stream(&generate_body(&pool[0][..8], 4, None))
            .unwrap()
            .collect()
            .unwrap()
            .tokens;
        assert_eq!(tokens.len(), 4);
        // the exposition parses and every lane histogram is well-formed
        let text = String::from_utf8(c.get("/metrics").unwrap().body).unwrap();
        let scrape = prom::parse(&text).unwrap_or_else(|e| panic!("bad exposition: {e}\n{text}"));
        // the audit: every sample has a valid name and a TYPE family
        for s in &scrape.samples {
            assert!(prom::valid_metric_name(&s.name), "bad metric name {:?}", s.name);
            assert!(scrape.type_of(&s.name).is_some(), "{} missing # TYPE", s.name);
        }
        for lane in ["classify", "generate"] {
            for stem in ["latency", "queue_wait", "execute", "ttft"] {
                let name = format!("esact_{lane}_{stem}_seconds");
                let h = scrape
                    .histogram(&name)
                    .unwrap_or_else(|| panic!("missing histogram {name}"));
                assert!(h.is_well_formed(), "{name} buckets are malformed");
                assert_eq!(scrape.type_of(&format!("{name}_bucket")), Some("histogram"));
            }
        }
        // histogram counts reconcile with the tier's own counters
        let served = scrape.value("esact_serve_requests_total").unwrap();
        let total = scrape.histogram("esact_classify_latency_seconds").unwrap();
        assert_eq!(total.count, served as u64, "classify count must match requests served");
        assert!(total.sum > 0.0, "two served requests took nonzero time");
        let sessions = scrape.value("esact_generate_sessions_total").unwrap();
        let gen_total = scrape.histogram("esact_generate_latency_seconds").unwrap();
        assert_eq!(gen_total.count, sessions as u64);
        assert!(scrape.value("esact_trace_spans_completed_total").unwrap() >= 3.0);
        // /debug/trace returns the spans, newest first, stages monotone
        let tr = c.get("/debug/trace?n=8").unwrap();
        assert_eq!(tr.status, 200);
        let doc = tr.json().unwrap();
        assert!(doc.get("completed").unwrap().as_usize().unwrap() >= 3);
        let spans = doc.get("spans").unwrap().as_arr().unwrap();
        assert!(spans.len() >= 3, "expected 3 completed spans, got {}", spans.len());
        for span in spans {
            assert!(span.get("fault").unwrap().as_str().is_none(), "no faults expected");
            let stages = span.get("stages").unwrap();
            let order = ["admitted", "queued", "dispatched", "exec_start", "exec_end", "done"];
            let ts: Vec<usize> = order
                .iter()
                .map(|s| {
                    stages
                        .get(s)
                        .and_then(|v| v.as_usize())
                        .unwrap_or_else(|| panic!("span missing stage {s}"))
                })
                .collect();
            assert!(ts.windows(2).all(|w| w[0] <= w[1]), "stages out of order: {ts:?}");
        }
        // n=1 caps the page size
        let one = c.get("/debug/trace?n=1").unwrap().json().unwrap();
        assert_eq!(one.get("spans").unwrap().as_arr().unwrap().len(), 1);
        gw.shutdown().unwrap();
    }
}
