//! Shared utilities: the cross-language PRNG, the ESWT tensor
//! container, matrices, stats + a criterion-style bench harness
//! (criterion is not in the vendored crate set), and a tiny
//! property-test driver (this image has no proptest crate).

pub mod bench;
pub mod eswt;
pub mod fault;
pub mod mat;
pub mod prop;
pub mod rng;
pub mod scratch;
pub mod stats;

use std::path::{Path, PathBuf};

/// Resolve the artifact directory for binaries, benches and examples:
/// `$ESACT_ARTIFACTS` if set, else `./artifacts` if present (running
/// from `rust/`), else `<crate root>/artifacts` — so `cargo run` /
/// `cargo bench` work from the workspace root and from `rust/` alike.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("ESACT_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let local = Path::new("artifacts");
    if local.is_dir() {
        return local.to_path_buf();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
