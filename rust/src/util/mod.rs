//! Shared utilities: the cross-language PRNG, the ESWT tensor
//! container, matrices, stats for the bench harness, and a tiny
//! property-test driver (this image has no proptest crate).

pub mod eswt;
pub mod mat;
pub mod prop;
pub mod rng;
pub mod stats;
