//! xoshiro256++ PRNG, bit-exact with `python/compile/data.py::Xoshiro256pp`.
//!
//! Both sides seed with splitmix64 so a shared integer seed regenerates
//! identical synthetic datasets / workload traces in python (train/export
//! time) and rust (serve/benchmark time) without shipping the data.

/// xoshiro256++ generator (Blackman & Vigna). Deterministic, seedable,
/// and fast enough for the simulator's workload generators.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via splitmix64 (same derivation as the python mirror).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            *slot = z ^ (z >> 31);
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via modulo — biased by < 2^-40 for our n,
    /// and (more importantly) identical to the python mirror.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Signed integer uniform in [lo, hi] inclusive.
    #[inline]
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller (used only on the rust side; not
    /// part of the cross-language contract).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Xoshiro256pp::new(42);
        let mut b = Xoshiro256pp::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Xoshiro256pp::new(42);
        let mut b = Xoshiro256pp::new(43);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Xoshiro256pp::new(7);
        for _ in 0..1000 {
            assert!(r.below(16) < 16);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256pp::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Xoshiro256pp::new(1);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "counts {:?}", counts);
        }
    }

    #[test]
    fn normal_mean_var() {
        let mut r = Xoshiro256pp::new(3);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
