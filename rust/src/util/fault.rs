//! Deterministic, seeded fault injection for chaos testing the serving
//! tier. Default-off: a [`FaultInjector`] only exists when a
//! [`FaultPlan`] was explicitly installed (programmatically or via the
//! `ESACT_FAULT_*` env knobs), and every injection site costs one
//! `Option` check when absent.
//!
//! **Determinism model.** Each [`FaultSite`] owns a monotone call
//! counter; the decision for call `n` at a site is a pure function of
//! `(seed, site, n)` — a fresh splitmix-seeded xoshiro256++ draw per
//! call, no shared RNG stream to race on. Thread interleaving can
//! change *which* job lands on a tripping call index, but never *how
//! many* calls trip out of a given call count — and because the tier's
//! recovery paths (classify retry, decode-session migration) are
//! bit-identical to fault-free execution, the served results are
//! reproducible regardless of which victim the scheduler picked.
//! Explicit nth-call triggers ([`FaultPlan::with_trigger`]) and
//! every-Nth periodic triggers ([`FaultPlan::with_every`]) make trip
//! *counts* exact for tests that reconcile metrics against the plan.
//!
//! Sites wired in this crate: replica classify/decode job execution
//! (`coordinator::replica`), paged KV block allocation
//! (`decode::paged`), and gateway socket writes (`net::gateway`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::rng::Xoshiro256pp;

/// Number of distinct injection sites (array sizing).
const N_SITES: usize = 4;

/// Where a fault can be injected.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// Start of a classify batch's execution on a replica worker
    /// (before the executor runs — the batch survives for requeue).
    ClassifyJob,
    /// Start of a decode slice on a replica worker (before the session
    /// advances — the dropped session releases its paged block refs,
    /// exactly like a real panic's unwind).
    DecodeJob,
    /// A paged KV pool block allocation (surfaces as `PoolExhausted`,
    /// the pool's real recoverable failure).
    PoolAlloc,
    /// A gateway socket write (the connection is treated as dead, as if
    /// the peer reset it).
    GatewayWrite,
}

impl FaultSite {
    /// Every site, in index order.
    pub const ALL: [FaultSite; N_SITES] =
        [FaultSite::ClassifyJob, FaultSite::DecodeJob, FaultSite::PoolAlloc, FaultSite::GatewayWrite];

    fn index(self) -> usize {
        match self {
            FaultSite::ClassifyJob => 0,
            FaultSite::DecodeJob => 1,
            FaultSite::PoolAlloc => 2,
            FaultSite::GatewayWrite => 3,
        }
    }

    /// Per-site domain-separation tag mixed into the decision seed.
    fn tag(self) -> u64 {
        // arbitrary distinct odd constants; stability matters only
        // within one process (plans carry the seed, not the tags)
        [0x9e37_79b9_7f4a_7c15, 0xbf58_476d_1ce4_e5b9, 0x94d0_49bb_1331_11eb, 0xd6e8_feb8_6659_fd93]
            [self.index()]
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultSite::ClassifyJob => "classify_job",
            FaultSite::DecodeJob => "decode_job",
            FaultSite::PoolAlloc => "pool_alloc",
            FaultSite::GatewayWrite => "gateway_write",
        }
    }
}

/// A reproducible fault schedule: per-site probabilities, every-Nth
/// periodic triggers, and explicit nth-call triggers, all under one
/// seed. Build with the `with_*` combinators; install via
/// `Server::with_fault_plan` (or the `ESACT_FAULT_*` env knobs on the
/// `serve` CLI).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    seed: u64,
    rates: [f64; N_SITES],
    /// Trip every `every[i]`-th call (1-based period; 0 = off).
    every: [u64; N_SITES],
    /// Explicit 0-based call indices that trip.
    triggers: [Vec<u64>; N_SITES],
}

impl FaultPlan {
    /// An empty plan (nothing trips) under `seed`.
    pub fn seeded(seed: u64) -> Self {
        Self { seed, ..Default::default() }
    }

    /// Trip each call at `site` independently with probability `rate`.
    pub fn with_rate(mut self, site: FaultSite, rate: f64) -> Self {
        self.rates[site.index()] = rate.clamp(0.0, 1.0);
        self
    }

    /// Trip every `n`-th call at `site` (calls n-1, 2n-1, … 0-based);
    /// `n = 0` disables the periodic trigger.
    pub fn with_every(mut self, site: FaultSite, n: u64) -> Self {
        self.every[site.index()] = n;
        self
    }

    /// Trip exactly the `nth` call (0-based) at `site`. May be chained
    /// to schedule several explicit faults.
    pub fn with_trigger(mut self, site: FaultSite, nth: u64) -> Self {
        self.triggers[site.index()].push(nth);
        self
    }

    /// True when no site can ever trip (the plan is a no-op).
    pub fn is_empty(&self) -> bool {
        self.rates.iter().all(|&r| r == 0.0)
            && self.every.iter().all(|&n| n == 0)
            && self.triggers.iter().all(|t| t.is_empty())
    }

    /// Read the CLI/CI env knobs: `ESACT_FAULT_SEED` (u64, default 0),
    /// `ESACT_FAULT_RATE` (f64, applied to the replica job sites), and
    /// `ESACT_FAULT_EVERY` (u64: deterministically trip every Nth
    /// replica job — what the chaos-smoke CI job uses so its expected
    /// trip count is exact). Returns `None` when no knob would ever
    /// trip, so the default serving path carries no injector at all.
    pub fn from_env() -> Option<Self> {
        let seed = std::env::var("ESACT_FAULT_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0);
        let rate: f64 =
            std::env::var("ESACT_FAULT_RATE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.0);
        let every: u64 =
            std::env::var("ESACT_FAULT_EVERY").ok().and_then(|s| s.parse().ok()).unwrap_or(0);
        let plan = FaultPlan::seeded(seed)
            .with_rate(FaultSite::ClassifyJob, rate)
            .with_rate(FaultSite::DecodeJob, rate)
            .with_every(FaultSite::ClassifyJob, every)
            .with_every(FaultSite::DecodeJob, every);
        if plan.is_empty() {
            None
        } else {
            Some(plan)
        }
    }

    /// The pure per-call decision: does call `n` at `site` trip?
    fn decide(&self, site: FaultSite, n: u64) -> bool {
        let i = site.index();
        if self.triggers[i].contains(&n) {
            return true;
        }
        if self.every[i] > 0 && (n + 1) % self.every[i] == 0 {
            return true;
        }
        let rate = self.rates[i];
        rate > 0.0 && {
            // one fresh splitmix-seeded stream per (seed, site, call):
            // no shared RNG state, so concurrent sites never perturb
            // each other's schedules
            let mix = self.seed ^ site.tag() ^ n.wrapping_mul(0xff51_afd7_ed55_8ccd);
            Xoshiro256pp::new(mix).f64() < rate
        }
    }
}

/// A live injector over a [`FaultPlan`]: cheap-clone handle (all clones
/// share the per-site call/trip counters). Call [`Self::trip`] at an
/// injection site; it advances the site's call counter and reports
/// whether this call faults.
#[derive(Clone)]
pub struct FaultInjector {
    plan: Arc<FaultPlan>,
    calls: Arc<[AtomicU64; N_SITES]>,
    trips: Arc<[AtomicU64; N_SITES]>,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        Self {
            plan: Arc::new(plan),
            calls: Arc::new(Default::default()),
            trips: Arc::new(Default::default()),
        }
    }

    /// One injection-site visit: returns `true` when this call faults.
    pub fn trip(&self, site: FaultSite) -> bool {
        let n = self.calls[site.index()].fetch_add(1, Ordering::SeqCst);
        let hit = self.plan.decide(site, n);
        if hit {
            self.trips[site.index()].fetch_add(1, Ordering::SeqCst);
        }
        hit
    }

    /// Calls observed at `site` so far.
    pub fn calls(&self, site: FaultSite) -> u64 {
        self.calls[site.index()].load(Ordering::SeqCst)
    }

    /// Faults injected at `site` so far.
    pub fn trips(&self, site: FaultSite) -> u64 {
        self.trips[site.index()].load(Ordering::SeqCst)
    }

    /// Faults injected across all sites.
    pub fn total_trips(&self) -> u64 {
        FaultSite::ALL.iter().map(|&s| self.trips(s)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_never_trips() {
        let inj = FaultInjector::new(FaultPlan::seeded(7));
        for _ in 0..100 {
            for &s in &FaultSite::ALL {
                assert!(!inj.trip(s));
            }
        }
        assert_eq!(inj.total_trips(), 0);
        assert_eq!(inj.calls(FaultSite::ClassifyJob), 100);
    }

    #[test]
    fn explicit_triggers_trip_exactly_those_calls() {
        let plan = FaultPlan::seeded(1)
            .with_trigger(FaultSite::DecodeJob, 0)
            .with_trigger(FaultSite::DecodeJob, 3);
        let inj = FaultInjector::new(plan);
        let got: Vec<bool> = (0..6).map(|_| inj.trip(FaultSite::DecodeJob)).collect();
        assert_eq!(got, vec![true, false, false, true, false, false]);
        assert_eq!(inj.trips(FaultSite::DecodeJob), 2);
        assert_eq!(inj.trips(FaultSite::ClassifyJob), 0, "sites are independent");
    }

    #[test]
    fn every_nth_is_periodic_and_exact() {
        let plan = FaultPlan::seeded(0).with_every(FaultSite::ClassifyJob, 3);
        let inj = FaultInjector::new(plan);
        let got: Vec<bool> = (0..9).map(|_| inj.trip(FaultSite::ClassifyJob)).collect();
        assert_eq!(got, vec![false, false, true, false, false, true, false, false, true]);
        assert_eq!(inj.trips(FaultSite::ClassifyJob), 3);
    }

    #[test]
    fn rate_schedule_is_seed_deterministic_and_roughly_calibrated() {
        let run = |seed| {
            let inj = FaultInjector::new(FaultPlan::seeded(seed).with_rate(FaultSite::PoolAlloc, 0.1));
            (0..2000).map(|_| inj.trip(FaultSite::PoolAlloc)).collect::<Vec<bool>>()
        };
        let a = run(42);
        assert_eq!(a, run(42), "same seed, same schedule, bit-for-bit");
        assert_ne!(a, run(43), "different seed, different schedule");
        let trips = a.iter().filter(|&&t| t).count();
        assert!((100..400).contains(&trips), "≈10% of 2000 calls should trip, got {trips}");
    }

    #[test]
    fn clones_share_counters() {
        let inj = FaultInjector::new(FaultPlan::seeded(0).with_trigger(FaultSite::GatewayWrite, 1));
        let c = inj.clone();
        assert!(!inj.trip(FaultSite::GatewayWrite));
        assert!(c.trip(FaultSite::GatewayWrite), "clone sees the shared call counter");
        assert_eq!(inj.trips(FaultSite::GatewayWrite), 1);
    }

    #[test]
    fn env_plan_parses_and_defaults_off() {
        // pure-plan behavior (env vars are process-global; exercise the
        // decide() path the env knobs configure instead of mutating env)
        let plan = FaultPlan::seeded(9)
            .with_rate(FaultSite::ClassifyJob, 0.5)
            .with_every(FaultSite::DecodeJob, 2);
        assert!(!plan.is_empty());
        assert!(FaultPlan::seeded(3).is_empty());
        assert!(plan.decide(FaultSite::DecodeJob, 1));
        assert!(!plan.decide(FaultSite::DecodeJob, 2));
    }
}
