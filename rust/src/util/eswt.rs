//! ESWT binary tensor container reader/writer — the interchange format
//! between the python compile path and the rust runtime.
//!
//! Layout (little-endian), mirrored exactly in `python/compile/io.py`:
//!
//! ```text
//! magic   b"ESWT"
//! version u32 = 1
//! count   u32
//! count x records:
//!   name_len u16, name bytes (utf-8)
//!   dtype    u8   (0 = f32, 1 = i32, 2 = u16)
//!   ndim     u8
//!   dims     ndim x u32
//!   data     raw, row-major
//! ```

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A named tensor loaded from an ESWT file.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
    U16 { dims: Vec<usize>, data: Vec<u16> },
}

impl Tensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. } | Tensor::I32 { dims, .. } | Tensor::U16 { dims, .. } => dims,
        }
    }

    pub fn len(&self) -> usize {
        self.dims().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as f32 slice, failing on other dtypes.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }
}

/// Read every tensor in an ESWT file into a name → tensor map.
pub fn read_eswt(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse_eswt(&bytes).with_context(|| format!("parsing {}", path.display()))
}

fn take<'a>(buf: &mut &'a [u8], n: usize) -> Result<&'a [u8]> {
    if buf.len() < n {
        bail!("truncated ESWT file (wanted {n} bytes, had {})", buf.len());
    }
    let (head, tail) = buf.split_at(n);
    *buf = tail;
    Ok(head)
}

fn read_u16(buf: &mut &[u8]) -> Result<u16> {
    Ok(u16::from_le_bytes(take(buf, 2)?.try_into().unwrap()))
}

fn read_u32(buf: &mut &[u8]) -> Result<u32> {
    Ok(u32::from_le_bytes(take(buf, 4)?.try_into().unwrap()))
}

fn read_u8(buf: &mut &[u8]) -> Result<u8> {
    Ok(take(buf, 1)?[0])
}

/// Parse ESWT bytes (exposed for in-memory tests).
pub fn parse_eswt(mut buf: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    let b = &mut buf;
    if take(b, 4)? != b"ESWT" {
        bail!("bad magic");
    }
    let version = read_u32(b)?;
    if version != 1 {
        bail!("unsupported ESWT version {version}");
    }
    let count = read_u32(b)?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let nlen = read_u16(b)? as usize;
        let name = String::from_utf8(take(b, nlen)?.to_vec()).context("tensor name utf-8")?;
        let code = read_u8(b)?;
        let ndim = read_u8(b)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(b)? as usize);
        }
        let n: usize = dims.iter().product();
        let tensor = match code {
            0 => {
                let raw = take(b, n * 4)?;
                let data = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Tensor::F32 { dims, data }
            }
            1 => {
                let raw = take(b, n * 4)?;
                let data = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Tensor::I32 { dims, data }
            }
            2 => {
                let raw = take(b, n * 2)?;
                let data = raw
                    .chunks_exact(2)
                    .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Tensor::U16 { dims, data }
            }
            other => bail!("unknown dtype code {other}"),
        };
        out.insert(name, tensor);
    }
    Ok(out)
}

/// Write tensors to an ESWT file (used by tests and trace exporters).
pub fn write_eswt(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(b"ESWT")?;
    f.write_all(&1u32.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        f.write_all(&(name.len() as u16).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        let (code, dims): (u8, &[usize]) = match t {
            Tensor::F32 { dims, .. } => (0, dims),
            Tensor::I32 { dims, .. } => (1, dims),
            Tensor::U16 { dims, .. } => (2, dims),
        };
        f.write_all(&[code, dims.len() as u8])?;
        for &d in dims {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        match t {
            Tensor::F32 { data, .. } => {
                for v in data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
            Tensor::I32 { data, .. } => {
                for v in data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
            Tensor::U16 { data, .. } => {
                for v in data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
        }
    }
    f.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BTreeMap<String, Tensor> {
        let mut m = BTreeMap::new();
        m.insert(
            "a".into(),
            Tensor::F32 {
                dims: vec![2, 3],
                data: vec![0.0, 1.5, -2.0, 3.25, f32::MIN_POSITIVE, 1e30],
            },
        );
        m.insert(
            "b".into(),
            Tensor::I32 {
                dims: vec![4],
                data: vec![-1, 0, 7, i32::MAX],
            },
        );
        m.insert(
            "tok".into(),
            Tensor::U16 {
                dims: vec![1, 2],
                data: vec![0, 65535],
            },
        );
        m
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("eswt_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let tensors = sample();
        write_eswt(&path, &tensors).unwrap();
        let out = read_eswt(&path).unwrap();
        assert_eq!(out, tensors);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_eswt(b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut m = BTreeMap::new();
        m.insert(
            "x".into(),
            Tensor::F32 {
                dims: vec![8],
                data: vec![1.0; 8],
            },
        );
        let dir = std::env::temp_dir().join(format!("eswt_trunc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        write_eswt(&path, &m).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(parse_eswt(&bytes[..bytes.len() - 3]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_version() {
        let mut bytes = b"ESWT".to_vec();
        bytes.extend(9u32.to_le_bytes());
        bytes.extend(0u32.to_le_bytes());
        assert!(parse_eswt(&bytes).is_err());
    }

    #[test]
    fn tensor_accessors() {
        let t = Tensor::F32 {
            dims: vec![2, 2],
            data: vec![1.0; 4],
        };
        assert_eq!(t.len(), 4);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
    }
}
