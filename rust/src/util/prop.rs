//! Minimal property-test driver (proptest is not in the vendored crate
//! set). `check` runs a seeded-random property over N cases and reports
//! the failing seed so a case can be replayed deterministically:
//!
//! ```no_run
//! use esact::util::prop;
//! prop::check(100, |rng| {
//!     let x = rng.int_in(-128, 127) as i32;
//!     let q = esact::quant::hlog_quantize(x);
//!     assert!(q.abs() >= x.abs() / 2);
//! });
//! ```

use crate::util::rng::Xoshiro256pp;

/// Base seed; change via `ESACT_PROP_SEED` to explore different corpora.
fn base_seed() -> u64 {
    std::env::var("ESACT_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xE5AC_7000)
}

/// Run `property` over `cases` independently-seeded RNGs. Panics with
/// the case seed on failure so it can be replayed.
pub fn check(cases: u64, property: impl Fn(&mut Xoshiro256pp)) {
    let base = base_seed();
    for i in 0..cases {
        let seed = base.wrapping_add(i);
        let mut rng = Xoshiro256pp::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng)
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| err.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed at case {i} (replay seed {seed}): {msg}");
        }
    }
}

/// Random vector helper for properties.
pub fn int8_vec(rng: &mut Xoshiro256pp, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.int_in(-128, 127) as i32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(50, |rng| {
            let v = rng.below(100);
            assert!(v < 100);
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check(50, |rng| {
            assert!(rng.below(10) < 5, "coin flip lost");
        });
    }

    #[test]
    fn int8_vec_in_range() {
        let mut rng = Xoshiro256pp::new(1);
        for &v in &int8_vec(&mut rng, 256) {
            assert!((-128..=127).contains(&v));
        }
    }
}
