//! Criterion-style micro-benchmark harness. The criterion crate is not
//! in this image's vendored crate set, so `benches/*.rs` are plain
//! `harness = false` binaries driving this zero-dependency shim: the
//! familiar `Criterion::bench_function(name, |b| b.iter(...))` surface
//! over `util::stats`'s warmup + sampling + percentile machinery.

use std::time::Instant;

use crate::util::stats::Summary;

pub use std::hint::black_box;

/// Harness entry point, mirroring criterion's `Criterion` driver.
pub struct Criterion {
    /// Timed samples collected per benchmark.
    reps: usize,
    /// Routine invocations amortized into one sample.
    iters: usize,
}

impl Default for Criterion {
    fn default() -> Self {
        Self { reps: 10, iters: 3 }
    }
}

impl Criterion {
    pub fn new() -> Self {
        Self::default()
    }

    /// Override the sampling plan (criterion's `sample_size` analogue).
    pub fn sampling(mut self, reps: usize, iters: usize) -> Self {
        assert!(reps > 0 && iters > 0);
        self.reps = reps;
        self.iters = iters;
        self
    }

    /// Run one named benchmark; prints a criterion-like report line and
    /// returns the [`Summary`] so callers can compute speedups.
    pub fn bench_function(
        &mut self,
        name: &str,
        mut f: impl FnMut(&mut Bencher),
    ) -> Summary {
        let mut b = Bencher {
            reps: self.reps,
            iters: self.iters,
            summary: None,
        };
        f(&mut b);
        let s = b
            .summary
            .unwrap_or_else(|| panic!("bench {name}: Bencher::iter was never called"));
        println!(
            "{name:<44} {:>11.2} µs/iter  (p50 {:>9.2}, p95 {:>9.2}, n={})",
            s.mean * 1e6,
            s.p50 * 1e6,
            s.p95 * 1e6,
            s.n
        );
        s
    }
}

/// Per-benchmark timer handle (criterion's `Bencher` analogue).
pub struct Bencher {
    reps: usize,
    iters: usize,
    summary: Option<Summary>,
}

impl Bencher {
    /// Time `routine`: warm up, then collect `reps` samples of `iters`
    /// amortized invocations each.
    pub fn iter<T>(&mut self, mut routine: impl FnMut() -> T) {
        for _ in 0..self.iters.min(3) {
            black_box(routine());
        }
        let samples: Vec<f64> = (0..self.reps)
            .map(|_| {
                let t = Instant::now();
                for _ in 0..self.iters {
                    black_box(routine());
                }
                t.elapsed().as_secs_f64() / self.iters as f64
            })
            .collect();
        self.summary = Some(Summary::of(&samples));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_function_collects_summary() {
        let mut c = Criterion::new().sampling(4, 2);
        let mut calls = 0u64;
        let s = c.bench_function("noop", |b| {
            b.iter(|| {
                calls += 1;
                calls
            })
        });
        assert_eq!(s.n, 4);
        assert!(s.mean >= 0.0);
        // warmup (2) + 4 samples × 2 iters
        assert_eq!(calls, 2 + 8);
    }

    #[test]
    #[should_panic(expected = "Bencher::iter was never called")]
    fn forgetting_iter_panics() {
        Criterion::new().bench_function("empty", |_b| {});
    }
}
