//! Minimal row-major matrix used across the SPLS algorithm, the model,
//! and the simulator. Deliberately small: this repo's hot paths are
//! either inside the AOT-compiled XLA executables (L1/L2) or inside the
//! cycle-accounting simulator, so the host-side matrix type optimizes
//! for clarity, not BLAS throughput (the int8 matmul in
//! `model::tensor` is the one routine that gets a blocked fast path).

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix over `T`.
#[derive(Clone, PartialEq)]
pub struct Mat<T> {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<T>,
}

impl<T: Copy + Default> Mat<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![T::default(); rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> T) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[T] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy row `src` over row `dst` (the recovery primitive: similar
    /// rows are restored by replicating their critical row).
    pub fn copy_row(&mut self, src: usize, dst: usize) {
        if src == dst {
            return;
        }
        let (lo, hi) = (src.min(dst), src.max(dst));
        let (a, b) = self.data.split_at_mut(hi * self.cols);
        let lo_row = &a[lo * self.cols..lo * self.cols + self.cols];
        let hi_row = &mut b[..self.cols];
        if src < dst {
            hi_row.copy_from_slice(lo_row);
        } else {
            // dst < src: copy from hi (src) into lo (dst)
            let tmp: Vec<T> = hi_row.to_vec();
            a[lo * self.cols..lo * self.cols + self.cols].copy_from_slice(&tmp);
        }
    }

    pub fn transpose(&self) -> Mat<T> {
        Mat::from_fn(self.cols, self.rows, |r, c| self[(c, r)])
    }
}

impl<T> Index<(usize, usize)> for Mat<T> {
    type Output = T;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &T {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl<T> IndexMut<(usize, usize)> for Mat<T> {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut T {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

impl<T: fmt::Debug> fmt::Debug for Mat<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows.min(8) {
            writeln!(f, "  {:?}", &self.data[r * self.cols..(r + 1) * self.cols])?;
        }
        if self.rows > 8 {
            writeln!(f, "  ... ({} more rows)", self.rows - 8)?;
        }
        write!(f, "]")
    }
}

pub type MatF = Mat<f32>;
pub type MatI = Mat<i32>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_rows() {
        let m = Mat::from_fn(3, 4, |r, c| (r * 10 + c) as i32);
        assert_eq!(m[(2, 3)], 23);
        assert_eq!(m.row(1), &[10, 11, 12, 13]);
    }

    #[test]
    fn copy_row_both_directions() {
        let mut m = Mat::from_fn(4, 3, |r, _| r as i32);
        m.copy_row(0, 2);
        assert_eq!(m.row(2), &[0, 0, 0]);
        m.copy_row(3, 1);
        assert_eq!(m.row(1), &[3, 3, 3]);
        m.copy_row(1, 1); // no-op
        assert_eq!(m.row(1), &[3, 3, 3]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_fn(3, 5, |r, c| (r * 5 + c) as i32);
        let t = m.transpose();
        assert_eq!(t.rows, 5);
        assert_eq!(t[(4, 2)], m[(2, 4)]);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_checked() {
        Mat::from_vec(2, 2, vec![1i32, 2, 3]);
    }
}
