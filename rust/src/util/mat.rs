//! Minimal row-major matrix used across the SPLS algorithm, the model,
//! and the simulator. Deliberately small: the host-side matrix type
//! optimizes for clarity, and the throughput-critical routines live in
//! `model::tensor` (slice-iterator ikj kernels the compiler can
//! autovectorize) and `model::engine` (the packed execution engine) —
//! see DESIGN.md §Host kernel layout.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix over `T`.
#[derive(Clone, PartialEq)]
pub struct Mat<T> {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<T>,
}

impl<T: Copy + Default> Mat<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![T::default(); rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> T) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[T] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy row `src` over row `dst` (the recovery primitive: similar
    /// rows are restored by replicating their critical row).
    pub fn copy_row(&mut self, src: usize, dst: usize) {
        if src == dst {
            return;
        }
        let (lo, hi) = (src.min(dst), src.max(dst));
        let (a, b) = self.data.split_at_mut(hi * self.cols);
        let lo_row = &a[lo * self.cols..lo * self.cols + self.cols];
        let hi_row = &mut b[..self.cols];
        if src < dst {
            hi_row.copy_from_slice(lo_row);
        } else {
            // dst < src: copy from hi (src) into lo (dst)
            let tmp: Vec<T> = hi_row.to_vec();
            a[lo * self.cols..lo * self.cols + self.cols].copy_from_slice(&tmp);
        }
    }

    pub fn transpose(&self) -> Mat<T> {
        let mut out = Mat::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// Transpose into a caller-owned buffer (the scratch-arena variant:
    /// `out` must already be `cols × rows`; every element is written).
    pub fn transpose_into(&self, out: &mut Mat<T>) {
        assert_eq!((out.rows, out.cols), (self.cols, self.rows), "transpose shape");
        for (r, row) in self.data.chunks_exact(self.cols.max(1)).enumerate() {
            for (c, &v) in row.iter().enumerate() {
                out.data[c * self.rows + r] = v;
            }
        }
    }

    /// Reshape to `rows × cols` and reset every element to `T::default()`
    /// — the scratch-buffer primitive: capacity is retained, so a reused
    /// buffer stops allocating once it has seen its steady-state shape.
    /// Use this when the next kernel *accumulates* into the buffer.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, T::default());
    }

    /// Reshape to `rows × cols` **without clearing** retained elements —
    /// for buffers whose next kernel overwrites every element anyway
    /// (`matmul_into`/`linear_into` zero-fill themselves;
    /// `layernorm_into`/`transpose_into` write every slot), sparing the
    /// redundant memset [`Mat::reset`] would pay. Newly grown capacity
    /// is still default-filled.
    pub fn reshape(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, T::default());
    }
}

impl<T> Index<(usize, usize)> for Mat<T> {
    type Output = T;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &T {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl<T> IndexMut<(usize, usize)> for Mat<T> {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut T {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

impl<T: fmt::Debug> fmt::Debug for Mat<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows.min(8) {
            writeln!(f, "  {:?}", &self.data[r * self.cols..(r + 1) * self.cols])?;
        }
        if self.rows > 8 {
            writeln!(f, "  ... ({} more rows)", self.rows - 8)?;
        }
        write!(f, "]")
    }
}

pub type MatF = Mat<f32>;
pub type MatI = Mat<i32>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_rows() {
        let m = Mat::from_fn(3, 4, |r, c| (r * 10 + c) as i32);
        assert_eq!(m[(2, 3)], 23);
        assert_eq!(m.row(1), &[10, 11, 12, 13]);
    }

    #[test]
    fn copy_row_both_directions() {
        let mut m = Mat::from_fn(4, 3, |r, _| r as i32);
        m.copy_row(0, 2);
        assert_eq!(m.row(2), &[0, 0, 0]);
        m.copy_row(3, 1);
        assert_eq!(m.row(1), &[3, 3, 3]);
        m.copy_row(1, 1); // no-op
        assert_eq!(m.row(1), &[3, 3, 3]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_fn(3, 5, |r, c| (r * 5 + c) as i32);
        let t = m.transpose();
        assert_eq!(t.rows, 5);
        assert_eq!(t[(4, 2)], m[(2, 4)]);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_checked() {
        Mat::from_vec(2, 2, vec![1i32, 2, 3]);
    }

    #[test]
    fn transpose_into_matches_transpose() {
        let m = Mat::from_fn(4, 6, |r, c| (r * 11 + c * 3) as i32);
        let mut out = Mat::zeros(6, 4);
        m.transpose_into(&mut out);
        assert_eq!(out, m.transpose());
    }

    #[test]
    fn reset_reshapes_zeroes_and_keeps_capacity() {
        let mut m = Mat::from_fn(8, 8, |_, _| 7i32);
        let cap = m.data.capacity();
        m.reset(3, 5);
        assert_eq!((m.rows, m.cols), (3, 5));
        assert!(m.data.iter().all(|&v| v == 0));
        m.reset(8, 8);
        assert_eq!(m.data.capacity(), cap, "steady-state reuse must not reallocate");
        assert!(m.data.iter().all(|&v| v == 0), "grow-back is zeroed too");
    }
}
