//! Small statistics helpers shared by the benchmark harness and reports
//! (this image has no criterion crate; `benches/` use `harness = false`
//! with these primitives).

use std::time::Instant;

/// Summary statistics over a sample of f64 measurements.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            max: sorted[n - 1],
        }
    }
}

/// Percentile by linear interpolation over a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Bounded sliding window of latency samples with percentile
/// extraction — the live-metrics primitive shared by the serving
/// tier's `LiveTier` and the HTTP gateway's request-latency gauge, so
/// the window/percentile mechanics exist exactly once.
#[derive(Debug)]
pub struct LatencyWindow {
    samples: std::collections::VecDeque<f64>,
    cap: usize,
}

/// Default retention: the most recent 1024 samples.
pub const DEFAULT_LATENCY_WINDOW: usize = 1024;

impl Default for LatencyWindow {
    fn default() -> Self {
        Self::new(DEFAULT_LATENCY_WINDOW)
    }
}

impl LatencyWindow {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "latency window needs at least one slot");
        Self { samples: std::collections::VecDeque::new(), cap }
    }

    /// Record one sample (seconds), evicting the oldest at capacity.
    pub fn push(&mut self, seconds: f64) {
        if self.samples.len() >= self.cap {
            self.samples.pop_front();
        }
        self.samples.push_back(seconds);
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// `(p50, p99)` over the retained window; zeros when empty.
    pub fn percentiles(&self) -> (f64, f64) {
        if self.samples.is_empty() {
            return (0.0, 0.0);
        }
        let mut sorted: Vec<f64> = self.samples.iter().copied().collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (percentile(&sorted, 0.50), percentile(&sorted, 0.99))
    }
}

/// Geometric mean (used for cross-benchmark speedup aggregation, matching
/// the paper's "on average" claims).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Pearson correlation between two equal-length samples — used for the
/// quantization similarity-fidelity analysis (paper Fig 7).
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (x, y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va == 0.0 || vb == 0.0 {
        return 0.0;
    }
    cov / (va * vb).sqrt()
}

/// Time a closure over `iters` iterations, returning per-iteration seconds.
pub fn time_per_iter<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    start.elapsed().as_secs_f64() / iters as f64
}

/// Bench runner: warm up, then collect `reps` timed samples of `f`
/// (each sample amortized over `iters` calls).
pub fn bench<F: FnMut()>(reps: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..iters.min(3) {
        f();
    }
    let samples: Vec<f64> = (0..reps).map(|_| time_per_iter(iters, &mut f)).collect();
    Summary::of(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
    }

    #[test]
    fn geomean_of_speedups() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let a = [1.0, 2.0, 3.0];
        assert!((pearson(&a, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson(&a, &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&a, &[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    #[should_panic]
    fn summary_rejects_empty() {
        Summary::of(&[]);
    }

    #[test]
    fn latency_window_bounds_and_percentiles() {
        let mut w = LatencyWindow::new(4);
        assert_eq!(w.percentiles(), (0.0, 0.0), "empty window reads zeros");
        for x in [1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        let (p50, p99) = w.percentiles();
        assert!((p50 - 2.5).abs() < 1e-12);
        assert!((p99 - 3.97).abs() < 1e-12);
        // pushing past capacity evicts the oldest sample (the 1.0)
        w.push(5.0);
        let (p50, _) = w.percentiles();
        assert!((p50 - 3.5).abs() < 1e-12);
        assert!(!w.is_empty());
    }
}
