//! Per-worker scratch arena for the packed execution engine
//! (`model::engine`): one named buffer per tensor the layer loop
//! touches, resized in place at the top of each forward —
//! [`Mat::reset`] (zeroing) for accumulation targets, [`Mat::reshape`]
//! (non-zeroing) for buffers the next kernel fully overwrites.
//! Buffers keep their capacity across calls, so a worker's steady-state
//! forwards allocate nothing — the first call with the largest shape
//! pays once, every later call reuses (see DESIGN.md §Host kernel
//! layout).
//!
//! Threading model: a `Scratch` is plain owned state. Long-lived owners
//! (a `DecodeState`, a bench loop) embed one directly; transient
//! callers on worker threads (the reference runtime's executables, the
//! serving planner) go through [`with_thread_scratch`], which hands out
//! one arena per OS thread. The closure must not re-enter
//! `with_thread_scratch` (RefCell would panic) — engine entry points
//! take `&mut Scratch` precisely so internals never need to.

use std::cell::RefCell;

use crate::util::mat::{Mat, MatF};

/// Named reusable buffers for one worker's forward passes. Field names
/// follow the transformer block's tensors; `part`/`out` are the
/// gathered-row staging buffers of the sparse path.
pub struct Scratch {
    /// Residual stream (L × D).
    pub x: MatF,
    /// LayerNorm output feeding QKV (L × D).
    pub h: MatF,
    /// Q / K / V activations (L × D dense; per-head shapes in sparse
    /// and decode paths — the compiled sparse path uses `k`/`v` as
    /// compact panel × Dh gather buffers).
    pub q: MatF,
    pub k: MatF,
    pub v: MatF,
    /// Transposed keys (D × L, dense/causal blocks only).
    pub kt: MatF,
    /// Attention scores (rows × L dense; the compiled sparse and masked
    /// paths reuse it as the flat CSR value buffer, 1 × nnz).
    pub s: MatF,
    /// Concatenated attention output (L × D).
    pub att: MatF,
    /// Projection / FFN-out staging (L × D).
    pub proj: MatF,
    /// Post-attention LayerNorm output (L × D).
    pub h2: MatF,
    /// FFN hidden activations (rows × F).
    pub ff: MatF,
    /// Gathered input rows (critical / MFI-representative tokens).
    pub part: MatF,
    /// Partial outputs awaiting recovery (rows × Dh or rows × D).
    pub out: MatF,
    /// Boolean softmax mask (rows × L).
    pub mask: Mat<bool>,
    /// Single-row boolean mask (the decode step's keep/all-true mask).
    pub flags: Vec<bool>,
    /// Index staging: kept-column gathers (masked block), kept-slot
    /// gathers (gated decode), representative maps.
    pub idx: Vec<usize>,
    /// Pooled classifier features as a 1 × D matrix.
    pub pooled: MatF,
    /// Classifier logits (1 × n_classes).
    pub logits: MatF,
}

impl Scratch {
    pub fn new() -> Self {
        let e = || MatF::zeros(0, 0);
        Self {
            x: e(),
            h: e(),
            q: e(),
            k: e(),
            v: e(),
            kt: e(),
            s: e(),
            att: e(),
            proj: e(),
            h2: e(),
            ff: e(),
            part: e(),
            out: e(),
            mask: Mat::zeros(0, 0),
            flags: Vec::new(),
            idx: Vec::new(),
            pooled: e(),
            logits: e(),
        }
    }
}

impl Default for Scratch {
    fn default() -> Self {
        Self::new()
    }
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::new());
}

/// Run `f` with this thread's scratch arena. Worker threads (serving
/// replicas, the planner's scoped threads) reuse one arena across all
/// the forwards they execute; do not nest calls.
pub fn with_thread_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_scratch_persists_capacity_across_calls() {
        let cap = with_thread_scratch(|sc| {
            sc.x.reset(16, 64);
            sc.x.data.capacity()
        });
        let (cap2, len) = with_thread_scratch(|sc| {
            sc.x.reset(8, 64);
            (sc.x.data.capacity(), sc.x.data.len())
        });
        assert_eq!(cap, cap2, "same arena, no reallocation");
        assert_eq!(len, 8 * 64);
    }
}
