//! Paper-style figure/table renderers: every entry in the experiment
//! index (DESIGN.md) has a `fig*`/`table*` function that regenerates
//! the corresponding result as text. `cargo run --release -- repro
//! <id>` calls these; `benches/repro_all.rs` runs the full set.

pub mod figures;
pub mod tables;

use std::fmt::Write as _;

/// Render a simple aligned table.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        for (i, c) in cells.iter().enumerate().take(ncol) {
            let _ = write!(out, "| {:<w$} ", c, w = widths[i]);
        }
        out.push_str("|\n");
    };
    line(&mut out, &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    for (i, w) in widths.iter().enumerate() {
        let _ = write!(out, "|{:-<w$}", "", w = w + 2);
        if i == ncol - 1 {
            out.push_str("|\n");
        }
    }
    for row in rows {
        line(&mut out, row);
    }
    out
}

/// An ASCII horizontal bar scaled to `max`.
pub fn bar(value: f64, max: f64, width: usize) -> String {
    let n = ((value / max).clamp(0.0, 1.0) * width as f64).round() as usize;
    "█".repeat(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let t = render_table(
            &["name", "v"],
            &[vec!["a".into(), "1.0".into()], vec!["long-name".into(), "2".into()]],
        );
        assert!(t.contains("| name"));
        assert!(t.contains("| long-name"));
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        // all data lines same rendered width
        assert_eq!(lines[0].chars().count(), lines[2].chars().count());
    }

    #[test]
    fn bar_scales() {
        assert_eq!(bar(0.5, 1.0, 10).chars().count(), 5);
        assert_eq!(bar(2.0, 1.0, 10).chars().count(), 10); // clamped
        assert_eq!(bar(0.0, 1.0, 10), "");
    }
}
