//! Figure reproductions (paper Figs 1, 3, 4, 6, 7, 15-21).
//!
//! Figures 3/4 and 16-19 run on the trained tiny substrate (measured
//! attention maps / sparsity); 1, 6, 7 are analytic; 15, 20, 21 combine
//! the 26-benchmark zoo with the cycle simulator. Every function
//! returns the rendered text so tests can assert on content.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

use crate::baselines::gpu::V100;
use crate::config::{HardwareConfig, SplsConfig};
use crate::model::{self, TestSet, TinyWeights};
use crate::quant::{self, QuantMethod};
use crate::report::{bar, render_table};
use crate::sim::{ablation, simulate_model, Features};
use crate::spls;
use crate::util::mat::MatI;
use crate::util::stats::geomean;
use crate::workloads::{all_benchmarks, model_gflops};

fn load_substrate(dir: &Path) -> Result<(TinyWeights, TestSet)> {
    Ok((
        TinyWeights::load(&dir.join("tiny_weights.bin"))?,
        TestSet::load(&dir.join("tiny_testset.bin"))?,
    ))
}

/// Fig 1: computation breakdown of BERT-Large and the global-similarity
/// break-even argument.
pub fn fig1() -> String {
    let mut out = String::new();
    let _ = writeln!(out, "Fig 1 — computation breakdown & global-similarity break-even\n");
    for cfg in [crate::config::bert_large(512), crate::config::bert_base(128)] {
        let b = model_gflops(&cfg);
        let _ = writeln!(
            out,
            "{:>11} L={:<4} total {:7.1} GFLOPs   MHA {:5.2}%  FFN {:5.2}%",
            cfg.name,
            cfg.seq_len,
            b.total_gflops,
            100.0 * b.mha_frac,
            100.0 * b.ffn_frac
        );
    }
    let _ = writeln!(out);
    for l in [128usize, 384, 512] {
        let be = crate::workloads::breakeven_rows_global_similarity(l);
        let local = crate::workloads::flops::local_similarity_comparisons(l, 8);
        let global = crate::workloads::flops::global_similarity_comparisons(l);
        let _ = writeln!(
            out,
            "L={l:<4} global sim needs >{be} rows pruned to break even; \
             comparisons global {global} vs local(w=8) {local} ({:.0}× fewer)",
            global as f64 / local as f64
        );
    }
    out
}

/// Fig 3: attention-distribution heatmaps showing local row similarity.
pub fn fig3(artifact_dir: &Path) -> Result<String> {
    let (w, set) = load_substrate(artifact_dir)?;
    let probs = model::attention_probs(&w, &set.tokens[0]);
    let mut out = String::new();
    let _ = writeln!(out, "Fig 3 — attention distribution (tiny substrate, layer 0)\n");
    for (h, mat) in probs[0].iter().enumerate().take(2) {
        let _ = writeln!(out, "head {h} (16×16 corner, █ = high attention):");
        for r in 0..16 {
            let mut line = String::new();
            for c in 0..16 {
                let v = mat[(r, c)];
                line.push(match v {
                    v if v > 0.2 => '█',
                    v if v > 0.08 => '▓',
                    v if v > 0.03 => '░',
                    _ => '·',
                });
            }
            let _ = writeln!(out, "  {line}");
        }
        // quantify within-window row similarity on the sparsified map
        let spa = spa_of_probs(mat);
        let sm = spls::local_similarity(&spa, 8, 0.6);
        let _ = writeln!(out, "  rows collapsed by w=8 similarity: {}/{}\n", sm.n_similar(), mat.rows);
    }
    Ok(out)
}

fn spa_of_probs(probs: &crate::util::mat::MatF) -> MatI {
    // scale probabilities to int for the integer SPA pipeline
    let pam = MatI::from_fn(probs.rows, probs.cols, |r, c| (probs[(r, c)] * 1000.0) as i32);
    let (spa, _) = spls::sparsify(&pam, 0.12);
    spa
}

/// Fig 4: percentage of heads exhibiting local similarity, by RWS band.
pub fn fig4(artifact_dir: &Path) -> Result<String> {
    let (w, set) = load_substrate(artifact_dir)?;
    let mut out = String::new();
    let _ = writeln!(out, "Fig 4 — heads by ratio of windows with inter-row similarity (w=8)\n");
    let mut bands = [0usize; 3]; // RWS > 0.5, 0.1..0.5, < 0.1
    let mut n_heads = 0usize;
    for tok in set.tokens.iter().take(8) {
        let probs = model::attention_probs(&w, tok);
        for layer in &probs {
            for mat in layer {
                let spa = spa_of_probs(mat);
                let rws = spls::ratio_windows_similar(&spa, 8, 0.6);
                n_heads += 1;
                if rws > 0.5 {
                    bands[0] += 1;
                } else if rws > 0.1 {
                    bands[1] += 1;
                } else {
                    bands[2] += 1;
                }
            }
        }
    }
    for (label, count) in [("RWS > 0.5 ", bands[0]), ("RWS 0.1-0.5", bands[1]), ("RWS < 0.1 ", bands[2])] {
        let pct = 100.0 * count as f64 / n_heads as f64;
        let _ = writeln!(out, "  {label}: {pct:5.1}%  {}", bar(pct, 100.0, 40));
    }
    let _ = writeln!(out, "\n  ({n_heads} head instances over 8 sequences; paper: most heads show local similarity)");

    // GPT-like causal section: same attention maps, causal-masked
    // (paper Fig 4 plots BERT and GPT separately; diagonal-dominant
    // causal heads show weaker but present window similarity)
    let _ = writeln!(out, "\n  causal (GPT-like) variant:");
    let mut c_bands = [0usize; 3];
    let mut c_heads = 0usize;
    for tok in set.tokens.iter().take(8) {
        let probs = model::attention_probs(&w, tok);
        for layer in &probs {
            for mat in layer {
                let mut pam = MatI::from_fn(mat.rows, mat.cols, |r, c| (mat[(r, c)] * 1000.0) as i32);
                spls::apply_causal_mask(&mut pam);
                let mask = spls::causal_topk_mask(&pam, 0.12);
                let spa = spls::topk::apply_mask(&pam, &mask);
                let sm = spls::causal_local_similarity(&spa, 8, 0.6);
                let n_windows = mat.rows.div_ceil(8);
                let mut similar_windows = 0usize;
                for w0 in (0..mat.rows).step_by(8) {
                    let w1 = (w0 + 8).min(mat.rows);
                    if (w0..w1).any(|r| sm.rep[r] != r) {
                        similar_windows += 1;
                    }
                }
                let rws = similar_windows as f64 / n_windows as f64;
                c_heads += 1;
                if rws > 0.5 {
                    c_bands[0] += 1;
                } else if rws > 0.1 {
                    c_bands[1] += 1;
                } else {
                    c_bands[2] += 1;
                }
            }
        }
    }
    for (label, count) in [("RWS > 0.5 ", c_bands[0]), ("RWS 0.1-0.5", c_bands[1]), ("RWS < 0.1 ", c_bands[2])] {
        let pct = 100.0 * count as f64 / c_heads as f64;
        let _ = writeln!(out, "  {label}: {pct:5.1}%  {}", bar(pct, 100.0, 40));
    }
    Ok(out)
}

/// Fig 6: 8-bit weight distribution vs PoT/APoT/HLog level sets.
pub fn fig6(artifact_dir: &Path) -> Result<String> {
    let (w, _) = load_substrate(artifact_dir)?;
    // histogram of |int8 weights| of the first projection
    let wq = &w.layers[0].wq;
    let (q, _) = quant::quantize_sym8(&wq.data);
    let mut hist = [0usize; 8]; // by leading-one octave
    for &v in &q {
        if v != 0 {
            hist[(31 - (v.abs() as u32).leading_zeros()) as usize] += 1;
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "Fig 6 — |weight| octave histogram vs quantization levels\n");
    let max = *hist.iter().max().unwrap() as f64;
    for (i, &h) in hist.iter().enumerate() {
        let _ = writeln!(
            out,
            "  [{:>3}..{:>3}) {:30} {h}",
            1 << i,
            1 << (i + 1),
            bar(h as f64, max, 30)
        );
    }
    let _ = writeln!(
        out,
        "\n  levels: PoT {} | HLog {} | APoT {}",
        quant::pot_levels(8).len(),
        quant::hlog_levels(8).len(),
        quant::apot_levels(8).len()
    );
    Ok(out)
}

/// Fig 7: quantization error + similarity fidelity of PoT/APoT/HLog.
pub fn fig7() -> String {
    let xs: Vec<i32> = (-127..=127).collect();
    let mut rows = Vec::new();
    for m in [QuantMethod::Pot, QuantMethod::Apot, QuantMethod::Hlog] {
        let err = quant::mean_abs_error(m, &xs);
        // similarity fidelity: correlation between true dot products and
        // quantized dot products over random int8 vector pairs
        let mut rng = crate::util::rng::Xoshiro256pp::new(7);
        let mut true_d = Vec::new();
        let mut quant_d = Vec::new();
        for _ in 0..200 {
            let a: Vec<i32> = (0..64).map(|_| rng.int_in(-128, 127) as i32).collect();
            let b: Vec<i32> = (0..64).map(|_| rng.int_in(-128, 127) as i32).collect();
            true_d.push(a.iter().zip(&b).map(|(&x, &y)| (x * y) as f64).sum::<f64>());
            quant_d.push(
                a.iter()
                    .zip(&b)
                    .map(|(&x, &y)| (m.quantize(x) * m.quantize(y)) as f64)
                    .sum::<f64>(),
            );
        }
        let fid = crate::util::stats::pearson(&true_d, &quant_d);
        rows.push(vec![m.name().to_string(), format!("{err:.2}"), format!("{fid:.4}")]);
    }
    format!(
        "Fig 7 — quantization comparison\n\n{}",
        render_table(&["method", "mean |err|", "dot-product fidelity (pearson)"], &rows)
    )
}

/// Fig 15: computation reduction across the 26 benchmarks.
pub fn fig15() -> String {
    let benches = all_benchmarks();
    let mut rows = Vec::new();
    for b in &benches {
        rows.push(vec![
            format!("{} {}", b.model.name, b.task),
            format!("{:.1}%", 100.0 * b.overall_reduction()),
            format!("{:.1}%", 100.0 * b.profile.qkv()),
            format!("{:.1}%", 100.0 * b.profile.attn),
            format!("{:.1}%", 100.0 * b.profile.ffn),
        ]);
    }
    let (overall, qkv, attn, ffn) = crate::workloads::bench26::zoo_averages(&benches);
    rows.push(vec![
        "AVERAGE (paper: 51.7 / 65.66 / 94.65 / 50.33)".into(),
        format!("{:.1}%", 100.0 * overall),
        format!("{:.1}%", 100.0 * qkv),
        format!("{:.1}%", 100.0 * attn),
        format!("{:.1}%", 100.0 * ffn),
    ]);
    format!(
        "Fig 15 — computation reduction (loss ≤ 1%)\n\n{}",
        render_table(&["benchmark", "overall", "QKV", "attention", "FFN"], &rows)
    )
}

/// One measured (s, w) sweep row for Figs 16/17/18/19.
fn sweep_eval(
    w: &TinyWeights,
    set: &TestSet,
    spls: &SplsConfig,
    method: QuantMethod,
    limit: usize,
) -> crate::model::EvalResult {
    model::eval_sparse(w, set, limit, spls, method)
}

/// Fig 16: Q sparsity & accuracy vs similarity threshold s and window w.
pub fn fig16(artifact_dir: &Path, limit: usize) -> Result<String> {
    let (w, set) = load_substrate(artifact_dir)?;
    let dense = model::eval_dense(&w, &set, limit);
    let mut rows = Vec::new();
    for window in [2usize, 4, 8, 16] {
        for s in [0.1f32, 0.3, 0.5, 0.7, 0.9] {
            let spls = SplsConfig { top_k: 0.12, sim_threshold: s, ffn_threshold: usize::MAX, window };
            let r = sweep_eval(&w, &set, &spls, QuantMethod::Hlog, limit);
            rows.push(vec![
                format!("{window}"),
                format!("{s:.1}"),
                format!("{:.3}", r.q_sparsity),
                format!("{:.4}", r.accuracy),
                format!("{:+.2}", r.loss_vs(&dense)),
            ]);
        }
    }
    Ok(format!(
        "Fig 16 — s/window sweep (k=0.12, no FFN sparsity; dense acc {:.4})\n\n{}",
        dense.accuracy,
        render_table(&["w", "s", "Q sparsity", "accuracy", "loss pts"], &rows)
    ))
}

/// Fig 17: Q sparsity & accuracy under HLog vs PoT vs APoT.
pub fn fig17(artifact_dir: &Path, limit: usize) -> Result<String> {
    let (w, set) = load_substrate(artifact_dir)?;
    let dense = model::eval_dense(&w, &set, limit);
    let mut rows = Vec::new();
    for m in [QuantMethod::Hlog, QuantMethod::Pot, QuantMethod::Apot] {
        for s in [0.2f32, 0.5, 0.8] {
            let spls = SplsConfig { top_k: 0.12, sim_threshold: s, ffn_threshold: usize::MAX, window: 8 };
            let r = sweep_eval(&w, &set, &spls, m, limit);
            rows.push(vec![
                m.name().into(),
                format!("{s:.1}"),
                format!("{:.3}", r.q_sparsity),
                format!("{:.4}", r.accuracy),
                format!("{:+.2}", r.loss_vs(&dense)),
            ]);
        }
    }
    Ok(format!(
        "Fig 17 — quantization methods: Q sparsity & accuracy (k=0.12, w=8)\n\n{}",
        render_table(&["method", "s", "Q sparsity", "accuracy", "loss pts"], &rows)
    ))
}

/// Fig 18: K sparsity under the quantization methods (flat in s).
pub fn fig18(artifact_dir: &Path, limit: usize) -> Result<String> {
    let (w, set) = load_substrate(artifact_dir)?;
    let mut rows = Vec::new();
    for m in [QuantMethod::Hlog, QuantMethod::Pot, QuantMethod::Apot] {
        let mut cells = vec![m.name().to_string()];
        for s in [0.2f32, 0.5, 0.8] {
            let spls = SplsConfig { top_k: 0.12, sim_threshold: s, ffn_threshold: usize::MAX, window: 8 };
            let r = sweep_eval(&w, &set, &spls, m, limit);
            cells.push(format!("{:.3}", r.kv_sparsity));
        }
        rows.push(cells);
    }
    Ok(format!(
        "Fig 18 — K sparsity vs s per quantization method (flat in s by construction)\n\n{}",
        render_table(&["method", "s=0.2", "s=0.5", "s=0.8"], &rows)
    ))
}

/// Fig 19: FFN threshold f vs FFN/Q sparsity and accuracy.
pub fn fig19(artifact_dir: &Path, limit: usize) -> Result<String> {
    let (w, set) = load_substrate(artifact_dir)?;
    let dense = model::eval_dense(&w, &set, limit);
    let mut rows = Vec::new();
    for f in [4usize, 3, 2, 1] {
        let spls = SplsConfig { top_k: 0.12, sim_threshold: 0.6, ffn_threshold: f, window: 8 };
        let r = sweep_eval(&w, &set, &spls, QuantMethod::Hlog, limit);
        rows.push(vec![
            format!("{f}"),
            format!("{:.3}", r.ffn_sparsity),
            format!("{:.3}", r.q_sparsity),
            format!("{:.4}", r.accuracy),
            format!("{:+.2}", r.loss_vs(&dense)),
        ]);
    }
    Ok(format!(
        "Fig 19 — FFN threshold sweep (k=0.12, s=0.6, w=8)\n\n{}",
        render_table(&["f", "FFN sparsity", "Q sparsity", "accuracy", "loss pts"], &rows)
    ))
}

/// Fig 20: end-to-end throughput vs V100, with the mechanism waterfall.
pub fn fig20() -> String {
    let hw = HardwareConfig::default();
    let spls = SplsConfig::default();
    let v100 = V100::default();
    let benches = all_benchmarks();
    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    let mut factors = [Vec::new(), Vec::new(), Vec::new()];
    for b in &benches {
        let batch = b.domain.batch();
        let gpu_per_seq = v100.batch_time(&b.model, batch) / batch as f64;
        let [dense, s, p, f] = ablation(&b.model, &hw, &spls, &b.profile);
        // 125 units run 125 sequences in parallel at per-unit latency
        let unit_time = |r: &crate::sim::SimResult| r.seconds(&hw) / 125.0;
        let e2e = gpu_per_seq / unit_time(&f);
        speedups.push(e2e);
        factors[0].push(dense.cycles as f64 / s.cycles as f64);
        factors[1].push(s.cycles as f64 / p.cycles as f64);
        factors[2].push(p.cycles as f64 / f.cycles as f64);
        rows.push(vec![
            format!("{} {}", b.model.name, b.task),
            format!("{:.2}×", gpu_per_seq / unit_time(&dense)),
            format!("{:.2}×", e2e),
        ]);
    }
    let g_dense = geomean(&rows.iter().map(|r| r[1].trim_end_matches('×').parse::<f64>().unwrap()).collect::<Vec<_>>());
    let g_e2e = geomean(&speedups);
    rows.push(vec![
        "GEOMEAN (paper: dense 2.42×, e2e 4.72×)".into(),
        format!("{g_dense:.2}×"),
        format!("{g_e2e:.2}×"),
    ]);
    format!(
        "Fig 20 — throughput vs V100 (125 units, V100-matched peak/BW)\n\n{}\n\
         mechanism waterfall (geomean): SPLS {:.2}× (paper 1.59×), \
         progressive {:.2}× (1.18×), dynalloc {:.2}× (1.04×)\n",
        render_table(&["benchmark", "dense ASIC", "ESACT e2e"], &rows),
        geomean(&factors[0]),
        geomean(&factors[1]),
        geomean(&factors[2]),
    )
}

/// Fig 21: end-to-end energy efficiency per benchmark.
pub fn fig21() -> String {
    let hw = HardwareConfig::default();
    let spls = SplsConfig::default();
    let benches = all_benchmarks();
    let mut rows = Vec::new();
    let mut effs = Vec::new();
    for b in &benches {
        let r = simulate_model(&b.model, &hw, &spls, &b.profile, Features::FULL);
        let eff = r.tops_per_watt(&hw);
        effs.push(eff);
        rows.push(vec![
            format!("{} {}", b.model.name, b.task),
            format!("{:.2}", eff),
            bar(eff, 6.0, 24),
        ]);
    }
    let avg = effs.iter().sum::<f64>() / effs.len() as f64;
    rows.push(vec!["AVERAGE (paper: 3.27)".into(), format!("{avg:.2}"), String::new()]);
    format!(
        "Fig 21 — end-to-end energy efficiency (TOPS/W)\n\n{}",
        render_table(&["benchmark", "TOPS/W", ""], &rows)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn analytic_figures_render() {
        assert!(fig1().contains("167"));
        assert!(fig7().contains("HLog"));
        assert!(fig15().contains("AVERAGE"));
    }

    #[test]
    fn fig20_has_waterfall() {
        let s = fig20();
        assert!(s.contains("GEOMEAN"));
        assert!(s.contains("progressive"));
    }

    #[test]
    fn fig21_has_average() {
        assert!(fig21().contains("AVERAGE"));
    }

    #[test]
    fn substrate_figures_render() {
        assert!(fig3(&dir()).unwrap().contains("head 0"));
        assert!(fig4(&dir()).unwrap().contains("RWS"));
        assert!(fig6(&dir()).unwrap().contains("levels"));
    }
}
