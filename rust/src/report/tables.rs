//! Table reproductions (paper Tables I-IV).

use std::fmt::Write as _;

use crate::config::{HardwareConfig, SplsConfig};
use crate::energy::area::{esact_breakdown, quant_unit_comparison, totals};
use crate::report::render_table;

/// Table I: qualitative comparison of sparse transformer accelerators.
pub fn table1() -> String {
    let rows = vec![
        vec!["Sanger", "relative magnitude", "4-bit quant", "High", "High", "Attn"],
        vec!["SpAtten", "relative magnitude", "progressive quant", "High", "High", "Attn & FFN"],
        vec!["DOTA", "relative magnitude", "low-rank", "High", "High", "Attn"],
        vec!["FACT", "relative magnitude", "PoT quant", "Low", "Low", "QKV & Attn"],
        vec!["TSAcc", "global similarity", "none", "High", "None", "QKV"],
        vec!["SpARC", "global similarity", "low-rank", "High", "High", "Attn"],
        vec!["ESACT", "local similarity", "HLog quant", "Low", "High", "QKV & Attn & FFN"],
    ]
    .into_iter()
    .map(|r| r.into_iter().map(String::from).collect())
    .collect::<Vec<Vec<String>>>();
    format!(
        "Table I — sparse transformer accelerators\n\n{}",
        render_table(
            &["accelerator", "sparse method", "prediction", "pred. cost", "sim. fidelity", "sparse positions"],
            &rows
        )
    )
}

/// Table II: ESACT area/power breakdown at 500 MHz.
pub fn table2() -> String {
    let hw = HardwareConfig::default();
    let breakdown = esact_breakdown(&hw);
    let (area, power) = totals(&breakdown);
    let mut rows: Vec<Vec<String>> = breakdown
        .iter()
        .map(|m| {
            vec![
                m.name.to_string(),
                format!("{:.2}", m.area_mm2),
                format!("{:.2}", m.power_mw),
            ]
        })
        .collect();
    rows.push(vec![
        "Total (paper: 5.09 mm², 792.12 mW)".into(),
        format!("{area:.2}"),
        format!("{power:.2}"),
    ]);
    format!(
        "Table II — ESACT area & power @500 MHz, 28 nm\n\n{}",
        render_table(&["module", "area (mm²)", "power (mW)"], &rows)
    )
}

/// Table III: quantization-unit area/power across accelerators.
pub fn table3() -> String {
    let v = quant_unit_comparison(128);
    let paper = [("Sanger", 0.23, 81.70), ("FACT", 0.14, 37.98), ("Enhance", 0.26, 80.76), ("ESACT", 0.17, 48.21)];
    let rows: Vec<Vec<String>> = v
        .iter()
        .map(|c| {
            let p = paper.iter().find(|(n, _, _)| *n == c.name).unwrap();
            vec![
                c.name.to_string(),
                format!("{:.3}", c.area_mm2),
                format!("{:.2}", p.1),
                format!("{:.1}", c.power_mw),
                format!("{:.2}", p.2),
            ]
        })
        .collect();
    format!(
        "Table III — prediction-unit cost (128 lanes, 28 nm)\n\n{}",
        render_table(
            &["method", "area mm² (model)", "(paper)", "power mW (model)", "(paper)"],
            &rows
        )
    )
}

/// Table IV: comparison with SpAtten and Sanger (normalized to 28 nm).
pub fn table4() -> String {
    let hw = HardwareConfig::default();
    let spls = SplsConfig::default();
    let accels = crate::baselines::attention_accelerators(&hw, &spls);
    let rows: Vec<Vec<String>> = accels
        .iter()
        .map(|a| {
            vec![
                a.name.to_string(),
                format!("{:.1}%", a.accuracy_loss_pct),
                format!("{:.0}", a.area_mm2 * 100.0).parse::<f64>().map(|v| format!("{:.2}", v / 100.0)).unwrap(),
                format!("{:.3}", a.power_w),
                format!("{:.0}", a.attn_gops),
                format!("{:.0}", a.energy_eff()),
                format!("{:.0}", a.area_eff()),
            ]
        })
        .collect();
    let mut out = format!(
        "Table IV — attention accelerators @28 nm\n\n{}",
        render_table(
            &["accelerator", "acc. loss", "area mm²", "power W", "attn GOPS", "GOPS/W", "GOPS/mm²"],
            &rows
        )
    );
    let eff = |n: &str| accels.iter().find(|a| a.name == n).unwrap().energy_eff();
    let _ = writeln!(
        out,
        "\nESACT vs SpAtten {:.2}× (paper 2.95×), vs Sanger {:.2}× (paper 2.26×)",
        eff("ESACT") / eff("SpAtten"),
        eff("ESACT") / eff("Sanger")
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tables_render() {
        assert!(table1().contains("ESACT"));
        assert!(table2().contains("Total"));
        assert!(table3().contains("FACT"));
        assert!(table4().contains("GOPS/W"));
    }

    #[test]
    fn table4_shows_esact_winning() {
        let t = table4();
        let line = t.lines().find(|l| l.contains("vs SpAtten")).unwrap();
        // extract the first ratio and check > 1
        let r: f64 = line
            .split('×')
            .next()
            .unwrap()
            .split_whitespace()
            .last()
            .unwrap()
            .parse()
            .unwrap();
        assert!(r > 1.5, "ESACT/SpAtten ratio {r}");
    }
}
