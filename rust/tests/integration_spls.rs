//! Cross-module integration: the SPLS pipeline from real trained
//! activations through planning, sparse execution, recovery, and FLOP
//! accounting — plus property tests over the whole pipeline.

use std::path::{Path, PathBuf};

use esact::config::SplsConfig;
use esact::model::{self, TinyWeights};
use esact::quant::QuantMethod;
use esact::spls;
use esact::util::mat::MatI;
use esact::util::prop;
use esact::util::rng::Xoshiro256pp;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn weights() -> TinyWeights {
    TinyWeights::load(&artifacts().join("tiny_weights.bin")).unwrap()
}

#[test]
fn sparse_forward_is_deterministic() {
    let w = weights();
    let mut rng = Xoshiro256pp::new(31);
    let (toks, _) = model::synth::gen_example(&mut rng, 64);
    let plans = model::plan_model(&w, &toks, &SplsConfig::default(), QuantMethod::Hlog);
    let a = model::forward_sparse(&w, &toks, &plans);
    let b = model::forward_sparse(&w, &toks, &plans);
    assert_eq!(a, b);
}

#[test]
fn plans_are_input_dependent() {
    // attention is input-dependent (paper §II) — different sequences
    // must produce different SPA masks
    let w = weights();
    let mut rng = Xoshiro256pp::new(32);
    let (t1, _) = model::synth::gen_example(&mut rng, 64);
    let (t2, _) = model::synth::gen_example(&mut rng, 64);
    let spls = SplsConfig::default();
    let p1 = model::plan_model(&w, &t1, &spls, QuantMethod::Hlog);
    let p2 = model::plan_model(&w, &t2, &spls, QuantMethod::Hlog);
    let mask_of = |p: &[spls::LayerPlan]| {
        p.iter()
            .flat_map(|l| l.heads.iter().flat_map(|h| h.mask.data.clone()))
            .collect::<Vec<bool>>()
    };
    assert_ne!(mask_of(&p1), mask_of(&p2));
}

#[test]
fn similar_rows_have_identical_attention_outputs() {
    // end-to-end recovery contract: in the sparse forward, a similar
    // row's attention output equals its critical row's output exactly.
    let w = weights();
    let mut rng = Xoshiro256pp::new(33);
    let (toks, _) = model::synth::gen_example(&mut rng, 64);
    let spls = SplsConfig { sim_threshold: 0.9, ..SplsConfig::default() };
    let plans = model::plan_model(&w, &toks, &spls, QuantMethod::Hlog);
    let any_similar = plans
        .iter()
        .any(|p| p.heads.iter().any(|h| h.sim.n_similar() > 0));
    assert!(any_similar, "threshold 0.9 should produce similar rows");
    // (the per-head replication itself is unit-tested; here we assert
    // the composed model still classifies sanely)
    let logits = model::forward_sparse(&w, &toks, &plans);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn flop_accounting_tracks_measured_sparsity() {
    let w = weights();
    let mut rng = Xoshiro256pp::new(34);
    let (toks, _) = model::synth::gen_example(&mut rng, 64);
    let cfg = esact::config::ModelConfig::new("tiny", 64, 64, 4, 2, 256, false);
    // aggressive config must reduce more than a conservative one
    let lo = model::plan_model(
        &w,
        &toks,
        &SplsConfig { sim_threshold: 0.1, ffn_threshold: 8, ..SplsConfig::default() },
        QuantMethod::Hlog,
    );
    let hi = model::plan_model(
        &w,
        &toks,
        &SplsConfig { sim_threshold: 0.9, ffn_threshold: 1, ..SplsConfig::default() },
        QuantMethod::Hlog,
    );
    let (r_lo, ..) = spls::computation_reduction(&cfg, &lo);
    let (r_hi, ..) = spls::computation_reduction(&cfg, &hi);
    assert!(r_hi > r_lo, "aggressive {r_hi} vs conservative {r_lo}");
}

#[test]
fn prop_pipeline_invariants_random_pams() {
    // property: for any integer PAM, the full plan pipeline maintains
    // its structural invariants
    prop::check(40, |rng| {
        let l = 8 + rng.below(56) as usize;
        let h = 1 + rng.below(4) as usize;
        let pams: Vec<MatI> = (0..h)
            .map(|_| MatI::from_fn(l, l, |_, _| rng.int_in(-5000, 5000) as i32))
            .collect();
        let spls_cfg = SplsConfig {
            top_k: 0.05 + rng.f64() as f32 * 0.9,
            sim_threshold: rng.f64() as f32,
            ffn_threshold: 1 + rng.below(4) as usize,
            window: 1 + rng.below(12) as usize,
        };
        let plan = spls::plan_layer(&pams, &spls_cfg);
        assert!(plan.ffn.validate(), "FFN chain broken");
        for head in &plan.heads {
            assert!(head.sim.validate(), "similarity map invalid");
            // sparsity fractions are probabilities
            for v in [head.q_sparsity(), head.kv_sparsity(), head.attn_sparsity()] {
                assert!((0.0..=1.0).contains(&v), "fraction {v}");
            }
            // every active column has ≥1 kept mask entry
            for &c in &head.active_cols {
                assert!(
                    (0..l).any(|r| head.mask[(r, c)]),
                    "active col {c} has no kept entry"
                );
            }
        }
    });
}

#[test]
fn prop_bit_level_unit_equals_quantized_arithmetic() {
    // property: the hardware-faithful SD→SJA→converter path equals
    // plain quantize-then-multiply for arbitrary shapes
    prop::check(30, |rng| {
        let m = 1 + rng.below(12) as usize;
        let k = 1 + rng.below(40) as usize;
        let n = 1 + rng.below(12) as usize;
        let x = MatI::from_fn(m, k, |_, _| rng.int_in(-128, 127) as i32);
        let w = MatI::from_fn(k, n, |_, _| rng.int_in(-128, 127) as i32);
        let unit = spls::predict_matmul(&x, &w);
        for r in 0..m {
            for c in 0..n {
                let want: i64 = (0..k)
                    .map(|i| {
                        esact::quant::hlog_quantize(x[(r, i)]) as i64
                            * esact::quant::hlog_quantize(w[(i, c)]) as i64
                    })
                    .sum();
                assert_eq!(unit[(r, c)] as i64, want);
            }
        }
    });
}

#[test]
fn quant_methods_rank_consistently_on_real_weights() {
    // Fig 17/18 structure: HLog's PAM keeps K-column choice close to
    // APoT (redundant levels) while PoT diverges
    let w = weights();
    let mut rng = Xoshiro256pp::new(35);
    let (toks, _) = model::synth::gen_example(&mut rng, 64);
    let spls_cfg = SplsConfig::default();
    let plan_for = |m| model::plan_model(&w, &toks, &spls_cfg, m);
    let hlog = plan_for(QuantMethod::Hlog);
    let apot = plan_for(QuantMethod::Apot);
    let pot = plan_for(QuantMethod::Pot);
    let cols = |p: &[spls::LayerPlan]| -> Vec<usize> {
        p.iter()
            .flat_map(|l| l.heads.iter().map(|h| h.active_cols.len()))
            .collect()
    };
    let (ch, ca, cp) = (cols(&hlog), cols(&apot), cols(&pot));
    let dist = |a: &[usize], b: &[usize]| -> i64 {
        a.iter().zip(b).map(|(&x, &y)| (x as i64 - y as i64).abs()).sum()
    };
    assert!(
        dist(&ch, &ca) <= dist(&ch, &cp) + 4,
        "HLog should track APoT more closely than PoT: {:?} {:?} {:?}",
        ch,
        ca,
        cp
    );
}
