//! Loopback integration tests for the HTTP gateway: the crucial
//! invariant is **bitwise parity** — classify logits and generated
//! token streams fetched over HTTP must be bit-identical to the
//! in-process `serve_replicated` / `serve_generate` results on the
//! committed tiny artifacts (the JSON transport encodes each f32 with
//! its shortest round-trip representation, which survives the
//! f64-parse + narrow on the way back; see `net::json`). Plus the
//! graceful-shutdown regression: an in-flight generate stream
//! completes, `/healthz` flips to draining first, and the listener
//! only closes once drained.

use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use esact::config::SplsConfig;
use esact::coordinator::{BatchPolicy, GenRequest, Mode, Reply, Request, Server};
use esact::decode::{DecodeConfig, Sampling};
use esact::net::client::{classify_body, generate_body, HttpClient};
use esact::net::{Gateway, GatewayConfig};
use esact::util::rng::Xoshiro256pp;

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn synth_seqs(seed: u64, n: usize, l: usize) -> Vec<Vec<i32>> {
    let mut rng = Xoshiro256pp::new(seed);
    (0..n).map(|_| esact::model::synth::gen_example(&mut rng, l).0).collect()
}

/// In-process reference: run the sequences through `serve_replicated`
/// on a fresh server and return the logits ordered by request id.
fn inprocess_classify(mode: Mode, seqs: &[Vec<i32>], replicas: usize) -> Vec<Vec<f32>> {
    let srv = Server::new(&artifacts_dir(), mode, SplsConfig::default()).unwrap();
    let (tx, rx) = mpsc::channel();
    let (rtx, rrx) = mpsc::channel();
    for (i, s) in seqs.iter().enumerate() {
        tx.send(Request { id: i as u64, tokens: s.clone(), arrived: Instant::now() }).unwrap();
    }
    drop(tx);
    let outcome = srv.serve_replicated(rx, rtx, BatchPolicy::default(), replicas).unwrap();
    assert_eq!(outcome.metrics.requests, seqs.len());
    let mut replies: Vec<Reply> = rrx.iter().collect();
    replies.sort_by_key(|r| r.id);
    replies.into_iter().map(|r| r.logits).collect()
}

/// In-process reference: one generate session's full token stream.
fn inprocess_generate(
    decode: DecodeConfig,
    prompt: &[i32],
    max_new: usize,
    sampling: Sampling,
) -> Vec<i32> {
    let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
    let (tx, rx) = mpsc::channel();
    let (ctx, crx) = mpsc::channel();
    tx.send(GenRequest {
        id: 0,
        prompt: prompt.to_vec(),
        prefix: None,
        max_new,
        sampling,
        arrived: Instant::now(),
    })
    .unwrap();
    drop(tx);
    let drain = std::thread::spawn(move || {
        let mut tokens = Vec::new();
        for chunk in crx.iter() {
            tokens.extend(chunk.tokens);
        }
        tokens
    });
    srv.serve_generate(rx, ctx, decode, 1, 4).unwrap();
    drain.join().unwrap()
}

fn start_gateway(cfg: GatewayConfig) -> (Gateway, String) {
    let srv = Arc::new(Server::new(&artifacts_dir(), cfg.mode, SplsConfig::default()).unwrap());
    let gw = Gateway::start(srv, cfg).unwrap();
    let addr = gw.local_addr().to_string();
    (gw, addr)
}

#[test]
fn http_classify_is_bit_identical_to_in_process_serving() {
    // SPLS mode: the HTTP path must route through the same planner +
    // plan cache + masked executor, so even the sparsity decisions are
    // on the line here, not just the dense kernels
    let seqs = synth_seqs(2024, 6, 64);
    let want = inprocess_classify(Mode::Spls, &seqs, 2);

    let cfg = GatewayConfig::builder().mode(Mode::Spls).replicas(2).build().unwrap();
    let (gw, addr) = start_gateway(cfg);
    let mut client = HttpClient::connect(&addr).unwrap();

    // one batched request carrying all six sequences
    let slices: Vec<&[i32]> = seqs.iter().map(|s| &s[..]).collect();
    let resp = client.post_json("/v1/classify", &classify_body(&slices)).unwrap();
    assert_eq!(resp.status, 200);
    let doc = resp.json().unwrap();
    let rows = doc.get("logits").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), seqs.len());
    for (row, want) in rows.iter().zip(&want) {
        let got = esact::net::json::to_f32_vec(row).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want) {
            assert_eq!(g.to_bits(), w.to_bits(), "HTTP logit {g} != in-process {w}");
        }
    }

    // and again one-at-a-time over a second connection — keep-alive
    // reuse and batch-of-one padding must not perturb anything
    let mut client2 = HttpClient::connect(&addr).unwrap();
    for (seq, want) in seqs.iter().zip(&want) {
        let resp = client2.post_json("/v1/classify", &classify_body(&[&seq[..]])).unwrap();
        assert_eq!(resp.status, 200);
        let doc = resp.json().unwrap();
        let got =
            esact::net::json::to_f32_vec(&doc.get("logits").unwrap().as_arr().unwrap()[0])
                .unwrap();
        for (g, w) in got.iter().zip(want) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }
    gw.shutdown().unwrap();
}

#[test]
fn http_generate_streams_are_bit_identical_to_in_process_serving() {
    let prompt = synth_seqs(7, 1, 64).remove(0)[..12].to_vec();
    let max_new = 10usize;
    let greedy = inprocess_generate(DecodeConfig::default(), &prompt, max_new, Sampling::Greedy);
    let sampled = inprocess_generate(
        DecodeConfig::default(),
        &prompt,
        max_new,
        Sampling::TopK { k: 4, temperature: 1.0, seed: 11 },
    );

    let cfg = GatewayConfig::builder().steps_per_slice(3).build().unwrap();
    let (gw, addr) = start_gateway(cfg);
    let mut client = HttpClient::connect(&addr).unwrap();

    let stream = client.generate_stream(&generate_body(&prompt, max_new, None)).unwrap();
    let got = stream.collect().unwrap();
    assert_eq!(got.tokens, greedy, "greedy stream must match in-process decode exactly");
    assert!(got.chunks >= 2, "tokens must arrive across chunks, not one buffered blob");
    assert!(got.ttft.is_some());

    // seeded top-k sampling is deterministic too — same seed over HTTP
    // must reproduce the in-process stream token for token
    let stream =
        client.generate_stream(&generate_body(&prompt, max_new, Some((4, 1.0, 11)))).unwrap();
    let got = stream.collect().unwrap();
    assert_eq!(got.tokens, sampled, "seeded top-k stream must replay bitwise");

    // malformed generate bodies answer 400 without breaking the conn,
    // and every error rides the unified envelope
    let bad = client.post_json("/v1/generate", "{\"prompt\": []}").unwrap();
    assert_eq!(bad.status, 400);
    let env = bad.error_envelope().expect("400 must carry the error envelope");
    assert_eq!(env.code, "bad_request");
    assert!(!env.message.is_empty());
    let bad = client.post_json("/v1/generate", "{\"max_new\": 4}").unwrap();
    assert_eq!(bad.status, 400);
    assert_eq!(bad.error_envelope().unwrap().code, "bad_request");
    gw.shutdown().unwrap();
}

#[test]
fn graceful_shutdown_completes_inflight_stream_and_flips_healthz_first() {
    // long generation (256 greedy tokens, 1 step per slice) so the
    // drain window is wide open while the stream is in flight
    let prompt = synth_seqs(3, 1, 64).remove(0)[..16].to_vec();
    let max_new = 256usize;
    let want = inprocess_generate(DecodeConfig::default(), &prompt, max_new, Sampling::Greedy);

    let cfg = GatewayConfig::builder().steps_per_slice(1).build().unwrap();
    let (gw, addr) = start_gateway(cfg);
    let handle = gw.shutdown_handle();

    let mut client = HttpClient::connect(&addr).unwrap();
    let mut stream = client.generate_stream(&generate_body(&prompt, max_new, None)).unwrap();
    // wait for the first generated token so the session is provably in
    // flight on the decode tier
    let mut tokens: Vec<i32> = loop {
        let (fresh, done) = stream.next_chunk().unwrap().expect("stream ended early");
        assert!(!done, "a 256-token stream cannot be done at the first token");
        if !fresh.is_empty() {
            break fresh;
        }
    };

    // flip the drain synchronously, then let another thread block on
    // the full join
    handle.shutdown();
    let joiner = std::thread::spawn(move || gw.join().unwrap());

    // /healthz must flip to draining while the stream is still open
    let mut saw_draining = false;
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline {
        match HttpClient::connect(&addr) {
            Ok(mut probe) => {
                let h = probe.get("/healthz").unwrap();
                if h.status == 503 {
                    let doc = h.json().unwrap();
                    assert_eq!(doc.get("status").unwrap().as_str(), Some("draining"));
                    saw_draining = true;
                    break;
                }
            }
            // listener already closed would mean the drain finished
            // before we observed it — fail below via the flag
            Err(_) => break,
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(saw_draining, "healthz must report draining while the stream is in flight");

    // the in-flight stream must run to completion despite the drain,
    // bit-identical to the in-process decode
    while let Some((fresh, _done)) = stream.next_chunk().unwrap() {
        tokens.extend(fresh);
    }
    assert_eq!(tokens, want, "drain must not cut or corrupt the in-flight stream");

    let report = joiner.join().unwrap();
    assert_eq!(report.generate.metrics.sessions, 1);
    assert_eq!(report.generate.metrics.tokens, max_new);

    // once drained, the listener is gone
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if std::net::TcpStream::connect(&addr).is_err() {
            break;
        }
        assert!(Instant::now() < deadline, "listener still accepting after drain");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Write raw bytes on a fresh socket and read everything the gateway
/// sends back until it closes the connection (or 500 ms of silence) —
/// for protocol-error paths where the response ends with a close.
fn raw_exchange(addr: &str, bytes: &[u8]) -> String {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
    // the gateway may answer and close before consuming everything we
    // send (oversized heads), so a failed tail write is expected
    let _ = s.write_all(bytes);
    let mut out = Vec::new();
    let mut buf = [0u8; 4096];
    loop {
        match s.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => out.extend_from_slice(&buf[..n]),
        }
    }
    String::from_utf8_lossy(&out).to_string()
}

/// Pull the envelope out of a raw HTTP response text: the body is the
/// part after the blank line, and must parse as {"error": {...}}.
fn envelope_of(raw: &str) -> (String, String) {
    let body = raw.split("\r\n\r\n").nth(1).unwrap_or("").trim();
    let doc = esact::net::json::Json::parse(body)
        .unwrap_or_else(|e| panic!("error body is not JSON ({e}): {body:?}"));
    let err = doc.get("error").expect("body must have an \"error\" object");
    (
        err.get("code").and_then(|c| c.as_str()).unwrap_or_default().to_string(),
        err.get("message").and_then(|m| m.as_str()).unwrap_or_default().to_string(),
    )
}

#[test]
fn error_envelope_is_uniform_across_paths() {
    // every non-2xx the gateway can produce — parser rejections,
    // protocol violations, route errors, and drain refusals — must
    // carry the same {"error":{"code","message"}} envelope
    let (gw, addr) = start_gateway(GatewayConfig::builder().max_body(512).build().unwrap());

    // 400: unparseable request head
    let raw = raw_exchange(&addr, b"total garbage\r\n\r\n");
    assert!(raw.starts_with("HTTP/1.1 400"), "got: {raw}");
    assert_eq!(envelope_of(&raw).0, "bad_request");

    // 413: declared body over the configured cap
    let raw = raw_exchange(
        &addr,
        b"POST /v1/classify HTTP/1.1\r\ncontent-length: 100000\r\n\r\n",
    );
    assert!(raw.starts_with("HTTP/1.1 413"), "got: {raw}");
    assert_eq!(envelope_of(&raw).0, "body_too_large");

    // 431: an absurdly long header line
    let mut big = b"GET /healthz HTTP/1.1\r\nx-padding: ".to_vec();
    big.resize(big.len() + 64 * 1024, b'a');
    big.extend_from_slice(b"\r\n\r\n");
    let raw = raw_exchange(&addr, &big);
    assert!(raw.starts_with("HTTP/1.1 431"), "got: {raw}");
    assert_eq!(envelope_of(&raw).0, "head_too_large");

    // 505: a protocol version the gateway does not speak
    let raw = raw_exchange(&addr, b"GET /healthz HTTP/2.0\r\n\r\n");
    assert!(raw.starts_with("HTTP/1.1 505"), "got: {raw}");
    assert_eq!(envelope_of(&raw).0, "http_version");

    // 501: an unsupported transfer-encoding on the request
    let raw = raw_exchange(
        &addr,
        b"POST /v1/classify HTTP/1.1\r\ntransfer-encoding: gzip\r\n\r\n",
    );
    assert!(raw.starts_with("HTTP/1.1 501"), "got: {raw}");
    assert_eq!(envelope_of(&raw).0, "unsupported_transfer");

    // 404 / 405 through the keep-alive client
    let mut client = HttpClient::connect(&addr).unwrap();
    let resp = client.get("/no/such/route").unwrap();
    assert_eq!(resp.status, 404);
    assert_eq!(resp.error_envelope().unwrap().code, "not_found");
    let resp = client.get("/v1/classify").unwrap();
    assert_eq!(resp.status, 405);
    assert_eq!(resp.error_envelope().unwrap().code, "method_not_allowed");

    // 503 after drain: pipeline the shutdown and a classify in one
    // segment — the first must answer 200, the second the envelope
    let resp = client.post_json("/admin/shutdown", "").unwrap();
    assert_eq!(resp.status, 200);
    let resp = client.post_json("/v1/classify", &classify_body(&[&[1, 2, 3][..]])).unwrap();
    assert_eq!(resp.status, 503);
    let env = resp.error_envelope().unwrap();
    assert_eq!(env.code, "unavailable");
    assert!(env.message.contains("drain"), "message was {:?}", env.message);
    gw.join().unwrap();
}

#[test]
fn http_batch_shapes_agree_with_each_other() {
    // a 3-sequence HTTP batch (padded to the 8-slot artifact) must
    // produce the same logits as three batch-of-one HTTP requests —
    // the gateway's batching is invisible to results
    let seqs = synth_seqs(99, 3, 64);
    let (gw, addr) = start_gateway(GatewayConfig::builder().build().unwrap());
    let mut client = HttpClient::connect(&addr).unwrap();
    let slices: Vec<&[i32]> = seqs.iter().map(|s| &s[..]).collect();
    let batched = client.post_json("/v1/classify", &classify_body(&slices)).unwrap();
    assert_eq!(batched.status, 200);
    let batched = batched.json().unwrap();
    let rows = batched.get("logits").unwrap().as_arr().unwrap().to_vec();
    for (i, seq) in seqs.iter().enumerate() {
        let solo = client.post_json("/v1/classify", &classify_body(&[&seq[..]])).unwrap();
        let solo = solo.json().unwrap();
        let a = esact::net::json::to_f32_vec(&rows[i]).unwrap();
        let b = esact::net::json::to_f32_vec(&solo.get("logits").unwrap().as_arr().unwrap()[0])
            .unwrap();
        assert_eq!(a, b, "batched vs solo logits differ for sequence {i}");
    }
    gw.shutdown().unwrap();
}
