//! Headline-number regression: the paper's central claim is a 52.03%
//! average computation reduction at the loss ≤ 1% operating point
//! (Fig 15 / abstract). Pin the reproduction inside a 45–60% corridor
//! over the synthetic bench26 workload zoo so sparsity changes cannot
//! silently regress the number, plus the measured-plan variant on
//! synthetic PAMs through `spls::computation_reduction`.

use esact::config::SplsConfig;
use esact::spls::{self, LayerPlan};
use esact::util::mat::MatI;
use esact::util::rng::Xoshiro256pp;
use esact::workloads::bench26::{all_benchmarks, zoo_averages};

/// The corridor around the paper's 52.03% headline.
const LO: f64 = 0.45;
const HI: f64 = 0.60;

#[test]
fn zoo_average_reduction_in_paper_corridor() {
    let benches = all_benchmarks();
    let (overall, _, _, _) = zoo_averages(&benches);
    assert!(
        (LO..=HI).contains(&overall),
        "zoo average computation reduction {overall:.4} left the 45–60% corridor \
         (paper: 52.03%)"
    );
}

#[test]
fn per_benchmark_reduction_never_collapses() {
    // no single workload may fall below 20% or above 90% — per-benchmark
    // deviations are bounded by construction (bench26::profile)
    for b in all_benchmarks() {
        let r = b.overall_reduction();
        assert!(
            (0.20..=0.90).contains(&r),
            "{} {}: reduction {r:.4} out of sane bounds",
            b.model.name,
            b.task
        );
    }
}

/// Synthetic PAM shaped like the bench26 encoder workloads, constructed
/// so the plan outcome mirrors the paper's operating point exactly:
///
/// * rows come in identical pairs (2t, 2t+1) → Q sparsity 50% via local
///   similarity (each 8-row window holds 4 pairs with pairwise-distinct
///   kept sets, so only true pairs merge);
/// * pair t's top-16 plateau sits at columns [(t%4)·8, (t%4)·8+16), so
///   the kept-column union is 40/128 → K/V sparsity 68.75% (paper 69%);
/// * 64 critical rows × 16 kept / 128² → attention sparsity 93.75%;
/// * every head votes 2t for token 2t+1 → FFN sparsity 50% (paper 50.33%).
fn structured_pams(l: usize, h: usize) -> Vec<MatI> {
    (0..h)
        .map(|_| {
            MatI::from_fn(l, l, |r, c| {
                let start = (r / 2 % 4) * 8;
                if (start..start + 16).contains(&c) {
                    100 // the plateau top-k keeps (keep_count(0.12, 128) = 16)
                } else {
                    (c % 50) as i32 // filler, strictly below the plateau
                }
            })
        })
        .collect()
}

#[test]
fn measured_plan_reduction_in_paper_corridor() {
    // run the *actual* plan pipeline (top-k → similarity → MFI) over the
    // structured PAMs and push the result through the FLOP ledger,
    // prediction overhead included — lands at ≈49.5% analytically
    let cfg = esact::config::ModelConfig::new("bench26-synth", 128, 768, 12, 12, 3072, false);
    let spls_cfg = SplsConfig::default();
    let pams = structured_pams(cfg.seq_len, cfg.n_heads);
    let plans: Vec<LayerPlan> = (0..cfg.n_layers)
        .map(|_| spls::plan_layer(&pams, &spls_cfg))
        .collect();
    // the construction's component sparsities must hold exactly
    let p0 = &plans[0];
    assert_eq!(p0.q_sparsity(), 0.5, "identical row pairs collapse");
    assert_eq!(p0.kv_sparsity(), 1.0 - 40.0 / 128.0, "40-column union");
    assert_eq!(p0.ffn_sparsity(), 0.5, "unanimous MFI votes");
    let (overall, qkv, attn, ffn) = spls::computation_reduction(&cfg, &plans);
    assert!(
        (LO..=HI).contains(&overall),
        "measured-plan reduction {overall:.4} left the 45–60% corridor \
         (components: qkv {qkv:.3}, attn {attn:.3}, ffn {ffn:.3})"
    );
    // component structure must match the paper's ordering: attention
    // sparsity dominates (94.65%), FFN and QKV sit near 50–66%
    assert!(attn > 0.85, "attention reduction {attn:.3}");
    assert!(attn > qkv && attn > ffn, "attention must dominate");
}

#[test]
fn reduction_is_deterministic_across_runs() {
    // the corridor check is only meaningful if the number is stable —
    // the parallel per-head planner must not introduce run-to-run drift
    let cfg = esact::config::ModelConfig::new("det", 64, 256, 4, 4, 1024, false);
    let random_pams = |seed: u64| -> Vec<MatI> {
        let mut rng = Xoshiro256pp::new(seed);
        (0..cfg.n_heads)
            .map(|_| MatI::from_fn(64, 64, |_, _| rng.int_in(-5000, 5000) as i32))
            .collect()
    };
    let run = || {
        let plans: Vec<LayerPlan> = (0..cfg.n_layers)
            .map(|i| spls::plan_layer(&random_pams(7 + i as u64), &SplsConfig::default()))
            .collect();
        spls::computation_reduction(&cfg, &plans)
    };
    assert_eq!(run(), run());
}
