//! Reproduction integration: every figure/table renderer runs and its
//! output carries the paper-anchored values — the "shape holds" checks
//! of the paper's figures/tables in executable form.

use std::path::{Path, PathBuf};

use esact::report::{figures, tables};

fn dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn fig1_bert_large_totals() {
    let t = figures::fig1();
    assert!(t.contains("BERT-Large"));
    // 167.5 GFLOPs ± rendering
    assert!(t.contains("167.") || t.contains("168."), "{t}");
    assert!(t.contains("38.4"), "MHA share missing: {t}");
}

#[test]
fn fig15_average_close_to_paper() {
    let t = figures::fig15();
    let avg_line = t.lines().find(|l| l.contains("AVERAGE")).unwrap();
    // overall column within a few points of 51.7%
    let overall: f64 = avg_line
        .split('|')
        .nth(2)
        .unwrap()
        .trim()
        .trim_end_matches('%')
        .parse()
        .unwrap();
    assert!((overall - 51.7).abs() < 4.0, "overall {overall}");
}

#[test]
fn fig20_who_wins_and_by_what_factor() {
    let t = figures::fig20();
    let line = t.lines().find(|l| l.contains("GEOMEAN")).unwrap();
    let cols: Vec<&str> = line.split('|').collect();
    let dense: f64 = cols[2].trim().trim_end_matches('×').parse().unwrap();
    let e2e: f64 = cols[3].trim().trim_end_matches('×').parse().unwrap();
    // paper: 2.42× dense, 4.72× end-to-end — shape must hold
    assert!((1.8..3.2).contains(&dense), "dense {dense}");
    assert!((3.2..6.5).contains(&e2e), "e2e {e2e}");
    assert!(e2e > dense * 1.4, "SPLS stack must add over dense ASIC");
}

#[test]
fn fig21_average_efficiency() {
    let t = figures::fig21();
    let line = t.lines().find(|l| l.contains("AVERAGE")).unwrap();
    let avg: f64 = line.split('|').nth(2).unwrap().trim().parse().unwrap();
    assert!((2.2..4.5).contains(&avg), "TOPS/W {avg}");
}

#[test]
fn table2_totals_near_paper() {
    let t = tables::table2();
    let line = t.lines().find(|l| l.contains("Total")).unwrap();
    let cols: Vec<&str> = line.split('|').collect();
    let area: f64 = cols[2].trim().parse().unwrap();
    let power: f64 = cols[3].trim().parse().unwrap();
    assert!((area - 5.09).abs() < 0.2, "area {area}");
    assert!((power - 792.12).abs() < 30.0, "power {power}");
}

#[test]
fn table4_ratios() {
    let t = tables::table4();
    let line = t.lines().find(|l| l.contains("vs SpAtten")).unwrap();
    // "ESACT vs SpAtten X.XX× (paper 2.95×), vs Sanger Y.YY× (paper 2.26×)"
    let nums: Vec<f64> = line
        .split('×')
        .filter_map(|s| s.split_whitespace().last())
        .filter_map(|s| s.parse().ok())
        .collect();
    assert!(nums[0] > 1.8 && nums[0] < 4.5, "vs SpAtten {}", nums[0]);
}

#[test]
fn substrate_sweeps_render_with_content() {
    // small limits keep this test quick while exercising the real path
    let f16 = figures::fig16(&dir(), 8).unwrap();
    assert!(f16.matches('\n').count() > 20, "sweep rows missing");
    let f18 = figures::fig18(&dir(), 8).unwrap();
    // Fig 18 property: K sparsity identical across s for each method
    for l in f18.lines().filter(|l| l.contains("HLog")) {
        let cells: Vec<&str> = l.split('|').map(str::trim).collect();
        assert_eq!(cells[2], cells[3], "K sparsity must be flat in s: {l}");
        assert_eq!(cells[3], cells[4], "K sparsity must be flat in s: {l}");
    }
    let f19 = figures::fig19(&dir(), 8).unwrap();
    // FFN sparsity should be monotone non-decreasing as f decreases
    let ffn: Vec<f64> = f19
        .lines()
        .filter(|l| l.starts_with("| 4") || l.starts_with("| 3") || l.starts_with("| 2") || l.starts_with("| 1"))
        .map(|l| l.split('|').nth(2).unwrap().trim().parse().unwrap())
        .collect();
    assert_eq!(ffn.len(), 4, "{f19}");
    for w in ffn.windows(2) {
        assert!(w[1] >= w[0] - 1e-9, "FFN sparsity not monotone: {ffn:?}");
    }
}
