//! Loopback integration tests for the observability subsystem: a live
//! gateway's `/metrics` exposition must round-trip through the in-repo
//! Prometheus text parser (`obs::prom`) with every per-lane latency
//! histogram well-formed and its `_count` reconciling with the tier's
//! own served-traffic counters, `/debug/trace` must serve completed
//! spans whose stage timestamps are monotone, the `trace_sample = 0`
//! knob must disable span minting without touching the histograms
//! (operators can turn tracing off; the latency SLO metrics stay), and
//! the closed-loop client must recover queue-wait/execute stage
//! medians from a scrape — the whole pipeline from hot-path
//! observation to operator-facing numbers, over real sockets.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use esact::config::SplsConfig;
use esact::coordinator::Server;
use esact::net::client::{
    classify_body, closed_loop_classify, generate_body, HttpClient,
};
use esact::net::{Gateway, GatewayConfig};
use esact::obs::prom;
use esact::util::rng::Xoshiro256pp;

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn synth_seqs(seed: u64, n: usize, l: usize) -> Vec<Vec<i32>> {
    let mut rng = Xoshiro256pp::new(seed);
    (0..n).map(|_| esact::model::synth::gen_example(&mut rng, l).0).collect()
}

fn start_gateway(cfg: GatewayConfig) -> (Gateway, String) {
    let srv = Arc::new(Server::new(&artifacts_dir(), cfg.mode, SplsConfig::default()).unwrap());
    let gw = Gateway::start(srv, cfg).unwrap();
    let addr = gw.local_addr().to_string();
    (gw, addr)
}

/// Drive both lanes, then scrape twice: the exposition must parse, all
/// eight per-lane histograms must be well-formed with counts that
/// reconcile against the tier's own counters, recovered quantiles must
/// be sane, and a second scrape must never move counts backwards.
#[test]
fn live_scrape_round_trips_every_histogram_and_reconciles_counts() {
    let cfg = GatewayConfig::builder().replicas(2).build().unwrap();
    let (gw, addr) = start_gateway(cfg);
    let mut c = HttpClient::connect(&addr).unwrap();
    let seqs = synth_seqs(31, 6, 64);
    for s in &seqs {
        let resp = c.post_json("/v1/classify", &classify_body(&[&s[..]])).unwrap();
        assert_eq!(resp.status, 200);
    }
    for s in seqs.iter().take(2) {
        let result =
            c.generate_stream(&generate_body(&s[..8], 4, None)).unwrap().collect().unwrap();
        assert_eq!(result.tokens.len(), 4);
    }

    let text = String::from_utf8(c.get("/metrics").unwrap().body).unwrap();
    let scrape = prom::parse(&text).unwrap_or_else(|e| panic!("bad exposition: {e}\n{text}"));
    for s in &scrape.samples {
        assert!(prom::valid_metric_name(&s.name), "bad metric name {:?}", s.name);
        assert!(scrape.type_of(&s.name).is_some(), "{} missing # TYPE", s.name);
    }

    let served = scrape.value("esact_serve_requests_total").unwrap() as u64;
    let sessions = scrape.value("esact_generate_sessions_total").unwrap() as u64;
    assert_eq!(served, seqs.len() as u64);
    assert_eq!(sessions, 2);
    for lane in ["classify", "generate"] {
        for stem in ["latency", "queue_wait", "execute", "ttft"] {
            let name = format!("esact_{lane}_{stem}_seconds");
            let h =
                scrape.histogram(&name).unwrap_or_else(|| panic!("missing histogram {name}"));
            assert!(h.is_well_formed(), "{name} buckets are malformed");
        }
    }
    // count reconciliation: the request-scoped histograms observe one
    // sample per served unit, so their _count rows must equal the
    // tier's own counters — a drift here means some code path records
    // latency without serving (or serves without recording)
    let classify_total = scrape.histogram("esact_classify_latency_seconds").unwrap();
    assert_eq!(classify_total.count, served);
    let gen_total = scrape.histogram("esact_generate_latency_seconds").unwrap();
    assert_eq!(gen_total.count, sessions);
    let ttft = scrape.histogram("esact_generate_ttft_seconds").unwrap();
    assert_eq!(ttft.count, sessions, "every stream produced a first chunk");
    // quantile recovery: medians are positive, bounded by the sum, and
    // ordered (p50 <= p99 within one histogram)
    let p50 = classify_total.quantile(0.5);
    let p99 = classify_total.quantile(0.99);
    assert!(p50 > 0.0, "median classify latency must be positive");
    assert!(p99 >= p50, "p99 {p99} < p50 {p50}");
    assert!(p50 <= classify_total.sum, "a single quantile cannot exceed the sum");

    // a second scrape is monotone: counts never move backwards
    let text2 = String::from_utf8(c.get("/metrics").unwrap().body).unwrap();
    let scrape2 = prom::parse(&text2).unwrap();
    let again = scrape2.histogram("esact_classify_latency_seconds").unwrap();
    assert!(again.count >= classify_total.count, "histogram count went backwards");
    assert!(
        scrape2.value("esact_trace_spans_completed_total").unwrap()
            >= (seqs.len() + 2) as f64,
        "every served unit completes a span at 1-in-1 sampling"
    );
    gw.shutdown().unwrap();
}

/// `/debug/trace` over a live socket: spans for both lanes, newest
/// first, monotone stage clocks, clean (fault-free) lineage.
#[test]
fn debug_trace_serves_monotone_spans_for_both_lanes() {
    let cfg = GatewayConfig::builder().replicas(1).build().unwrap();
    let (gw, addr) = start_gateway(cfg);
    let mut c = HttpClient::connect(&addr).unwrap();
    let seqs = synth_seqs(47, 3, 64);
    for s in &seqs {
        assert_eq!(c.post_json("/v1/classify", &classify_body(&[&s[..]])).unwrap().status, 200);
    }
    let result =
        c.generate_stream(&generate_body(&seqs[0][..8], 3, None)).unwrap().collect().unwrap();
    assert_eq!(result.tokens.len(), 3);

    let resp = c.get("/debug/trace?n=16").unwrap();
    assert_eq!(resp.status, 200);
    let doc = resp.json().unwrap();
    let spans = doc.get("spans").unwrap().as_arr().unwrap();
    assert!(spans.len() >= 4, "3 classify + 1 generate spans, got {}", spans.len());
    let mut lanes_seen = (false, false);
    for span in spans {
        match span.get("lane").unwrap().as_str().unwrap() {
            "classify" => lanes_seen.0 = true,
            "generate" => lanes_seen.1 = true,
            other => panic!("unknown lane {other:?}"),
        }
        assert_eq!(span.get("attempts").unwrap().as_usize().unwrap(), 1);
        assert!(span.get("fault").unwrap().as_str().is_none(), "fault-free run");
        let stages = span.get("stages").unwrap();
        // the tier-side stages are always present; the gateway's two
        // socket-side stages (accepted, parsed) are backdated after
        // submit returns, so include them in the monotonicity check
        // whenever they have landed rather than requiring them
        for s in ["admitted", "queued", "dispatched", "exec_start"] {
            assert!(stages.get(s).is_some(), "span missing stage {s}");
        }
        let order =
            ["accepted", "parsed", "admitted", "queued", "dispatched", "exec_start"];
        let ts: Vec<usize> =
            order.iter().filter_map(|s| stages.get(s).and_then(|v| v.as_usize())).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "stages out of order: {ts:?}");
        let done = stages.get("done").and_then(|v| v.as_usize()).unwrap();
        assert!(done >= ts[ts.len() - 1], "done precedes dispatch");
    }
    assert!(lanes_seen.0 && lanes_seen.1, "both lanes must leave spans");
    // the generate span carries the prefill/decode phase split
    let gen_span = spans
        .iter()
        .find(|s| s.get("lane").unwrap().as_str() == Some("generate"))
        .unwrap();
    assert!(gen_span.get("stages").unwrap().get("first_chunk").is_some());
    assert!(gen_span.get("prefill_ns").unwrap().as_usize().unwrap() > 0);
    gw.shutdown().unwrap();
}

/// The sampling knob: `trace_sample = 0` must mint no spans at all,
/// while the latency histograms keep observing every request — the
/// SLO metrics are not opt-out, only the per-request traces are.
#[test]
fn sampling_off_disables_spans_but_never_the_histograms() {
    let cfg = GatewayConfig::builder().replicas(1).trace_sample(0).build().unwrap();
    let (gw, addr) = start_gateway(cfg);
    let mut c = HttpClient::connect(&addr).unwrap();
    let seqs = synth_seqs(83, 4, 64);
    for s in &seqs {
        assert_eq!(c.post_json("/v1/classify", &classify_body(&[&s[..]])).unwrap().status, 200);
    }
    let doc = c.get("/debug/trace?n=16").unwrap().json().unwrap();
    assert_eq!(doc.get("completed").unwrap().as_usize().unwrap(), 0);
    assert_eq!(doc.get("spans").unwrap().as_arr().unwrap().len(), 0);
    let text = String::from_utf8(c.get("/metrics").unwrap().body).unwrap();
    let scrape = prom::parse(&text).unwrap();
    let total = scrape.histogram("esact_classify_latency_seconds").unwrap();
    assert_eq!(total.count, seqs.len() as u64, "histograms must not be sampled away");
    assert_eq!(scrape.value("esact_trace_spans_completed_total"), Some(0.0));
    gw.shutdown().unwrap();
}

/// The closed-loop client recovers per-stage medians from a scrape:
/// after a run, `LoadReport::scrape_stages` parses the live exposition
/// and yields queue-wait and execute medians consistent with the
/// whole-request latency it measured itself from the client side.
#[test]
fn closed_loop_report_recovers_stage_medians_from_the_scrape() {
    let cfg = GatewayConfig::builder().replicas(2).build().unwrap();
    let (gw, addr) = start_gateway(cfg);
    let pool = synth_seqs(59, 4, 64);
    let mut report = closed_loop_classify(&addr, 2, 12, &pool).unwrap();
    assert_eq!(report.errors, 0);
    assert!(report.queue_wait_p50_ms.is_none(), "medians unset before the scrape");
    let mut probe = HttpClient::connect(&addr).unwrap();
    report.scrape_stages(&mut probe).unwrap();
    let queue_wait = report.queue_wait_p50_ms.expect("queue-wait median from scrape");
    let execute = report.execute_p50_ms.expect("execute median from scrape");
    assert!(queue_wait >= 0.0);
    assert!(execute > 0.0, "executing a forward takes nonzero time");
    // stage medians are pieces of the whole, but the scrape-side
    // quantile interpolates inside a log2 bucket with no min/max clamp,
    // so it can overshoot the true median by up to one bucket width
    // (2x) — bound against the client-observed whole-request p99 with
    // that factor
    assert!(
        execute <= 2.0 * report.p99_ms(),
        "execute median {execute} ms > 2x request p99 {} ms",
        report.p99_ms()
    );
    gw.shutdown().unwrap();
}
