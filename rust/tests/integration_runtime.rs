//! Runtime/serving integration: runtime executables vs the host model,
//! masked execution vs the host sparse dataflow, serving accuracy, and
//! failure injection on the artifact path. Runs against whichever
//! backend is active (`runtime::reference` by default; the PJRT backend
//! with `--features pjrt`); PJRT-specific tests are feature-gated.

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::Instant;

use esact::config::SplsConfig;
use esact::coordinator::server::Mode;
use esact::coordinator::{BatchPolicy, Request, Server};
use esact::model::{self, TestSet, TinyWeights};
use esact::quant::QuantMethod;
use esact::runtime::{Arg, ArtifactSet};
use esact::util::rng::Xoshiro256pp;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn aot_dense_matches_host_over_many_seeds() {
    let set = ArtifactSet::load(&artifacts()).unwrap();
    let w = TinyWeights::load(&artifacts().join("tiny_weights.bin")).unwrap();
    let mut rng = Xoshiro256pp::new(41);
    for _ in 0..6 {
        let (toks, _) = model::synth::gen_example(&mut rng, 64);
        let aot = set.dense_b1.run_f32(&[Arg::I32(&toks, &[1, 64])]).unwrap();
        let host = model::forward_dense(&w, &toks);
        for (a, h) in aot.iter().zip(&host) {
            assert!((a - h).abs() < 3e-2, "{a} vs {h}");
        }
        assert_eq!(
            model::tensor::argmax(&aot),
            model::tensor::argmax(&host),
            "classification diverges"
        );
    }
}

#[test]
fn aot_masked_matches_host_sparse_dataflow() {
    // The masked executable fed with SPLS masks must agree with the
    // host forward_sparse (same masks, same recovery semantics).
    let set = ArtifactSet::load(&artifacts()).unwrap();
    let w = TinyWeights::load(&artifacts().join("tiny_weights.bin")).unwrap();
    let mut rng = Xoshiro256pp::new(42);
    let spls = SplsConfig::default();
    for _ in 0..4 {
        let (toks, _) = model::synth::gen_example(&mut rng, 64);
        let plans = model::plan_model(&w, &toks, &spls, QuantMethod::Hlog);
        let l = 64usize;
        let mut masks = Vec::new();
        for p in &plans {
            for h in &p.heads {
                for r in 0..l {
                    let src = h.sim.rep[r];
                    for c in 0..l {
                        masks.push(if h.mask[(src, c)] { 1.0f32 } else { 0.0 });
                    }
                }
            }
        }
        let aot = set
            .masked_b1
            .run_f32(&[Arg::I32(&toks, &[1, l]), Arg::F32(&masks, &[1, 2, 4, l, l])])
            .unwrap();
        let host = model::forward_sparse(&w, &toks, &plans);
        // The two dataflows differ slightly by design: the host computes
        // Q only for critical rows and replicates their outputs, while
        // the masked executable computes every row's own Q under the
        // replicated mask. Logits must correlate strongly; the argmax
        // may flip only on near-ties.
        let ad: Vec<f64> = aot.iter().map(|&v| v as f64).collect();
        let hd: Vec<f64> = host.iter().map(|&v| v as f64).collect();
        let corr = esact::util::stats::pearson(&ad, &hd);
        assert!(corr > 0.99, "logit correlation {corr}: aot {aot:?} host {host:?}");
        let (pa, ph) = (model::tensor::argmax(&aot), model::tensor::argmax(&host));
        if pa != ph {
            // tolerate flips only when the host's top-2 margin is small
            let mut sorted = host.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let margin = sorted[0] - sorted[1];
            assert!(margin < 1.5, "class flip with margin {margin}: aot {aot:?} host {host:?}");
        }
    }
}

#[test]
fn batch8_consistent_with_batch1() {
    let set = ArtifactSet::load(&artifacts()).unwrap();
    let mut rng = Xoshiro256pp::new(43);
    let seqs: Vec<Vec<i32>> = (0..8)
        .map(|_| model::synth::gen_example(&mut rng, 64).0)
        .collect();
    let flat: Vec<i32> = seqs.iter().flatten().copied().collect();
    let batched = set.dense_b8.run_f32(&[Arg::I32(&flat, &[8, 64])]).unwrap();
    for (i, s) in seqs.iter().enumerate() {
        let single = set.dense_b1.run_f32(&[Arg::I32(s, &[1, 64])]).unwrap();
        for (b, o) in batched[i * 16..(i + 1) * 16].iter().zip(&single) {
            assert!((b - o).abs() < 1e-4, "batch {b} vs single {o}");
        }
    }
}

#[test]
fn serving_accuracy_matches_offline_eval() {
    let dir = artifacts();
    let set = TestSet::load(&dir.join("tiny_testset.bin")).unwrap();
    let srv = Server::new(&dir, Mode::Dense, SplsConfig::default()).unwrap();
    let n = 32usize;
    let (tx, rx) = mpsc::channel::<Request>();
    let (rtx, rrx) = mpsc::channel();
    for i in 0..n {
        tx.send(Request {
            id: i as u64,
            tokens: set.tokens[i].clone(),
            arrived: Instant::now(),
        })
        .unwrap();
    }
    drop(tx);
    let labels: Vec<i32> = set.labels[..n].to_vec();
    let collector = std::thread::spawn(move || {
        rrx.iter()
            .filter(|r: &esact::coordinator::Reply| {
                model::tensor::argmax(&r.logits) as i32 == labels[r.id as usize]
            })
            .count()
    });
    let metrics = srv.serve(rx, rtx, BatchPolicy::default()).unwrap();
    let correct = collector.join().unwrap();
    assert_eq!(metrics.requests, n);
    // offline harness on the same prefix
    let w = TinyWeights::load(&dir.join("tiny_weights.bin")).unwrap();
    let offline = model::eval_dense(&w, &set, n);
    let served_acc = correct as f64 / n as f64;
    assert!(
        (served_acc - offline.accuracy).abs() < 1e-9,
        "served {served_acc} vs offline {}",
        offline.accuracy
    );
}

#[test]
fn replicated_spls_serving_is_bit_stable_across_replica_counts() {
    // logits depend only on the request's tokens (per-sequence
    // execution + per-request SPLS planning), so replica count and
    // batch composition must not change a single bit of any reply —
    // and the plan cache must serve the repeated wave.
    let dir = artifacts();
    let set = TestSet::load(&dir.join("tiny_testset.bin")).unwrap();
    let srv = Server::new(&dir, Mode::Spls, SplsConfig::default()).unwrap();
    let n = 8usize;
    let run = |n_replicas: usize| {
        let (tx, rx) = mpsc::channel::<Request>();
        let (rtx, rrx) = mpsc::channel();
        for i in 0..n {
            tx.send(Request {
                id: i as u64,
                tokens: set.tokens[i].clone(),
                arrived: Instant::now(),
            })
            .unwrap();
        }
        drop(tx);
        let collector = std::thread::spawn(move || {
            let mut replies: Vec<esact::coordinator::Reply> = rrx.iter().collect();
            replies.sort_by_key(|r| r.id);
            replies
        });
        let outcome = srv
            .serve_replicated(rx, rtx, BatchPolicy::default(), n_replicas)
            .unwrap();
        (outcome, collector.join().unwrap())
    };
    let (one, replies_one) = run(1);
    let (two, replies_two) = run(2);
    assert_eq!(one.metrics.requests, n);
    assert_eq!(two.metrics.requests, n);
    assert_eq!(two.per_replica.len(), 2);
    assert_eq!(replies_one.len(), n);
    for (a, b) in replies_one.iter().zip(&replies_two) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.logits, b.logits, "replica count changed served logits");
    }
    // the second run replays the same 16 sequences: plan cache serves it
    assert!(
        two.metrics.plan_cache.hits >= n,
        "expected ≥ {n} plan-cache hits, got {:?}",
        two.metrics.plan_cache
    );
}

// ---------------------------------------------------------------------
// failure injection
// ---------------------------------------------------------------------

#[test]
fn missing_artifact_dir_fails_loudly() {
    let err = match ArtifactSet::load(Path::new("/nonexistent")) {
        Err(e) => e,
        Ok(_) => panic!("load of missing dir must fail"),
    };
    assert!(err.to_string().contains("make artifacts"), "{err}");
}

#[cfg(feature = "pjrt-xla")]
#[test]
fn corrupt_hlo_text_fails_at_load_not_at_run() {
    let dir = std::env::temp_dir().join(format!("esact_corrupt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.hlo.txt");
    std::fs::write(&path, "HloModule garbage\nENTRY main { broken }").unwrap();
    let client = xla::PjRtClient::cpu().unwrap();
    assert!(esact::runtime::Executable::load(&client, &path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn wrong_shape_inputs_rejected() {
    let set = ArtifactSet::load(&artifacts()).unwrap();
    let toks = vec![0i32; 32]; // wrong: compiled for 64
    assert!(set.dense_b1.run_f32(&[Arg::I32(&toks, &[1, 32])]).is_err());
}

#[test]
fn truncated_weights_file_rejected() {
    let bytes = std::fs::read(artifacts().join("tiny_weights.bin")).unwrap();
    let dir = std::env::temp_dir().join(format!("esact_trunc_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("w.bin");
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert!(TinyWeights::load(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
