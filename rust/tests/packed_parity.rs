//! Packed-engine parity: `model::engine::PackedModel` must be
//! **bit-identical** to the unpacked `model::transformer` references on
//! every forward path — dense, masked, causal and SPLS-sparse — plus
//! planning and token-by-token decode, across randomized model shapes,
//! tokens, masks and SPLS operating points. This is the contract that
//! lets the serving tier run exclusively on the packed engine without
//! re-baselining a single golden value.

use std::sync::Arc;

use esact::config::SplsConfig;
use esact::decode::{DecodeConfig, DecodeEngine, DecodeMode, DecodeState};
use esact::model::transformer::LM_HEAD_PAR_VOCAB;
use esact::model::weights::LayerWeights;
use esact::model::{
    forward_causal_hidden, forward_dense, forward_masked, forward_sparse, lm_logits_row,
    next_token_logits, plan_model, PackedModel, TinyConfig, TinyWeights,
};
use esact::quant::QuantMethod;
use esact::util::mat::MatF;
use esact::util::rng::Xoshiro256pp;
use esact::util::scratch::Scratch;

fn rand_vec(rng: &mut Xoshiro256pp, n: usize, lo: f64, hi: f64) -> Vec<f32> {
    (0..n).map(|_| (lo + rng.f64() * (hi - lo)) as f32).collect()
}

fn rand_mat(rng: &mut Xoshiro256pp, r: usize, c: usize) -> MatF {
    MatF::from_vec(r, c, rand_vec(rng, r * c, -0.25, 0.25))
}

/// A randomly-shaped, randomly-weighted tiny transformer — the packed
/// engine must agree with the reference on *any* config, not just the
/// trained 64/64/4/2 artifact shape.
fn synth_weights(rng: &mut Xoshiro256pp, cfg: TinyConfig) -> TinyWeights {
    let (d, f) = (cfg.d_model, cfg.d_ffn);
    let layers = (0..cfg.n_layers)
        .map(|_| LayerWeights {
            wq: rand_mat(rng, d, d),
            bq: rand_vec(rng, d, -0.1, 0.1),
            wk: rand_mat(rng, d, d),
            bk: rand_vec(rng, d, -0.1, 0.1),
            wv: rand_mat(rng, d, d),
            bv: rand_vec(rng, d, -0.1, 0.1),
            wo: rand_mat(rng, d, d),
            bo: rand_vec(rng, d, -0.1, 0.1),
            ln1_g: rand_vec(rng, d, 0.8, 1.2),
            ln1_b: rand_vec(rng, d, -0.1, 0.1),
            w1: rand_mat(rng, d, f),
            b1: rand_vec(rng, f, -0.1, 0.1),
            w2: rand_mat(rng, f, d),
            b2: rand_vec(rng, d, -0.1, 0.1),
            ln2_g: rand_vec(rng, d, 0.8, 1.2),
            ln2_b: rand_vec(rng, d, -0.1, 0.1),
        })
        .collect();
    TinyWeights {
        embed: rand_mat(rng, cfg.vocab, d),
        pos: rand_mat(rng, cfg.seq_len, d),
        layers,
        lnf_g: rand_vec(rng, d, 0.8, 1.2),
        lnf_b: rand_vec(rng, d, -0.1, 0.1),
        cls_w: rand_mat(rng, d, cfg.n_classes),
        cls_b: rand_vec(rng, cfg.n_classes, -0.1, 0.1),
        cfg,
    }
}

/// Shape sweep: odd head counts, non-square FFNs, 1–3 layers.
fn configs() -> Vec<TinyConfig> {
    vec![
        TinyConfig {
            vocab: 24,
            seq_len: 24,
            d_model: 24,
            n_heads: 3,
            n_layers: 1,
            d_ffn: 40,
            n_classes: 5,
        },
        TinyConfig {
            vocab: 40,
            seq_len: 40,
            d_model: 32,
            n_heads: 4,
            n_layers: 3,
            d_ffn: 64,
            n_classes: 7,
        },
        TinyConfig {
            vocab: 16,
            seq_len: 20,
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            d_ffn: 48,
            n_classes: 3,
        },
    ]
}

fn rand_tokens(rng: &mut Xoshiro256pp, l: usize, vocab: usize) -> Vec<i32> {
    (0..l).map(|_| rng.below(vocab as u64) as i32).collect()
}

#[test]
fn packed_dense_masked_causal_bit_identical_over_randomized_shapes() {
    let mut rng = Xoshiro256pp::new(0xE5AC7);
    for cfg in configs() {
        let w = Arc::new(synth_weights(&mut rng, cfg));
        let pm = PackedModel::new(Arc::clone(&w));
        let mut sc = Scratch::new();
        for _ in 0..4 {
            let l = 1 + rng.below(cfg.seq_len as u64) as usize;
            let toks = rand_tokens(&mut rng, l, cfg.vocab);
            assert_eq!(
                pm.forward_dense(&toks, &mut sc),
                forward_dense(&w, &toks),
                "dense diverged at cfg {cfg:?} L {l}"
            );
            // random masks, dense enough to keep rows alive but with
            // plenty of pruned (and occasionally fully-masked) rows
            let n_mask = cfg.n_layers * cfg.n_heads * l * l;
            let masks: Vec<f32> = (0..n_mask)
                .map(|_| if rng.f64() < 0.35 { 0.0 } else { 1.0 })
                .collect();
            assert_eq!(
                pm.forward_masked(&toks, &masks, &mut sc),
                forward_masked(&w, &toks, &masks),
                "masked diverged at cfg {cfg:?} L {l}"
            );
            assert_eq!(
                pm.forward_causal_hidden(&toks, &mut sc).data,
                forward_causal_hidden(&w, &toks).data,
                "causal hidden diverged at cfg {cfg:?} L {l}"
            );
        }
    }
}

#[test]
fn packed_planning_and_sparse_bit_identical_over_randomized_plans() {
    let mut rng = Xoshiro256pp::new(0x5EED5);
    for cfg in configs() {
        let w = Arc::new(synth_weights(&mut rng, cfg));
        let pm = PackedModel::new(Arc::clone(&w));
        let mut sc = Scratch::new();
        for method in [QuantMethod::Hlog, QuantMethod::Pot] {
            let l = 2 + rng.below((cfg.seq_len - 2) as u64) as usize;
            let toks = rand_tokens(&mut rng, l, cfg.vocab);
            let spls = SplsConfig {
                top_k: (0.05 + rng.f64() * 0.9) as f32,
                sim_threshold: (rng.f64() * 1.2) as f32,
                ffn_threshold: 1 + rng.below(3) as usize,
                window: 4 + rng.below(8) as usize,
            };
            let want_plans = plan_model(&w, &toks, &spls, method);
            let got_plans = pm.plan_model(&toks, &spls, method, &mut sc);
            assert_eq!(got_plans, want_plans, "plans diverged at cfg {cfg:?} {method:?}");
            assert_eq!(
                pm.forward_sparse(&toks, &got_plans, &mut sc),
                forward_sparse(&w, &toks, &want_plans),
                "sparse forward diverged at cfg {cfg:?} {method:?}"
            );
        }
    }
}

#[test]
fn packed_decode_bit_identical_to_unpacked_prefill_over_shapes() {
    // token-by-token decode runs entirely on the packed engine; the
    // iterated-prefill reference runs entirely unpacked — equality at
    // every length crosses the packed/unpacked boundary per step
    let mut rng = Xoshiro256pp::new(0xDEC0DE);
    for cfg in configs() {
        let w = Arc::new(synth_weights(&mut rng, cfg));
        let eng = Arc::new(DecodeEngine::new(Arc::clone(&w)));
        let seq = rand_tokens(&mut rng, cfg.seq_len.min(12), cfg.vocab);
        let mut st = DecodeState::new(eng, DecodeConfig::default());
        for t in 1..=seq.len() {
            let got = st.push(seq[t - 1]);
            let want = next_token_logits(&w, &seq[..t]);
            assert_eq!(got, want, "decode diverged at cfg {cfg:?} length {t}");
        }
    }
}

#[test]
fn packed_spls_decode_with_open_gates_equals_dense_decode_over_shapes() {
    // top_k = 1, similarity off, FFN skipping off: the Spls machinery
    // (incremental predictor on the packed int8 operands) runs but
    // gates nothing, so logits must equal the dense decode path
    let mut rng = Xoshiro256pp::new(0x9A7E5);
    for cfg in configs() {
        let w = Arc::new(synth_weights(&mut rng, cfg));
        let eng = Arc::new(DecodeEngine::new(Arc::clone(&w)));
        let spls = SplsConfig {
            top_k: 1.0,
            sim_threshold: -1.0,
            ffn_threshold: usize::MAX,
            window: 8,
        };
        let dcfg = DecodeConfig { mode: DecodeMode::Spls, spls, ..DecodeConfig::default() };
        let mut sparse = DecodeState::new(Arc::clone(&eng), dcfg);
        let mut dense = DecodeState::new(eng, DecodeConfig::default());
        for &t in &rand_tokens(&mut rng, 8, cfg.vocab) {
            assert_eq!(sparse.push(t), dense.push(t), "cfg {cfg:?}");
        }
    }
}

#[test]
fn lm_head_parallel_path_bit_identical_to_scalar_reference() {
    // a vocab past the rayon threshold forces the parallel fan-out;
    // every logit must match the scalar index-arithmetic reference the
    // slice-iterator kernel replaced
    let mut rng = Xoshiro256pp::new(0x10617);
    let cfg = TinyConfig {
        vocab: LM_HEAD_PAR_VOCAB + 37,
        seq_len: 8,
        d_model: 24,
        n_heads: 2,
        n_layers: 1,
        d_ffn: 32,
        n_classes: 4,
    };
    let w = synth_weights(&mut rng, cfg);
    let row = rand_vec(&mut rng, cfg.d_model, -1.0, 1.0);
    let got = lm_logits_row(&w, &row);
    assert_eq!(got.len(), cfg.vocab);
    let want: Vec<f32> = (0..cfg.vocab)
        .map(|v| {
            let mut acc = 0.0f32;
            for (c, &x) in row.iter().enumerate() {
                acc += x * w.embed[(v, c)];
            }
            acc
        })
        .collect();
    assert_eq!(got, want, "parallel LM head changed bits");
}

#[test]
fn packed_parity_holds_on_the_trained_artifacts() {
    // the synthetic sweep proves shape generality; this pins the real
    // serving substrate (trained weights, L = 64) end to end
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let w = Arc::new(TinyWeights::load(&dir.join("tiny_weights.bin")).unwrap());
    let pm = PackedModel::new(Arc::clone(&w));
    let mut sc = Scratch::new();
    let mut rng = Xoshiro256pp::new(0xA27);
    let toks = rand_tokens(&mut rng, 64, 64);
    assert_eq!(pm.forward_dense(&toks, &mut sc), forward_dense(&w, &toks));
    let spls = SplsConfig::default();
    let plans = pm.plan_model(&toks, &spls, QuantMethod::Hlog, &mut sc);
    assert_eq!(plans, plan_model(&w, &toks, &spls, QuantMethod::Hlog));
    assert_eq!(
        pm.forward_sparse(&toks, &plans, &mut sc),
        forward_sparse(&w, &toks, &plans)
    );
}
