//! Paged-KV correctness on the trained artifacts: the headline
//! contract is that a single uncontended session reading K/V through
//! the block table is **bit-identical** to the contiguous cache — per
//! step, on the raw f32 logits, in both the dense path and the
//! evicting SPLS path (private blocks evict exactly like contiguous
//! slots). Plus: a session attaching to a published prefix generates
//! the same stream as a cold one, and prefix sharing peaks at strictly
//! fewer pool blocks than replaying the prompt privately per session.

use std::sync::Arc;

use esact::config::SplsConfig;
use esact::decode::{
    DecodeConfig, DecodeEngine, DecodeMode, DecodeState, GenSession, PagedDecodeState, PagedPool,
    Sampling,
};
use esact::model::tensor::argmax;
use esact::model::TinyWeights;
use esact::util::rng::Xoshiro256pp;

fn weights() -> Arc<TinyWeights> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny_weights.bin");
    Arc::new(TinyWeights::load(&p).unwrap())
}

fn engine() -> Arc<DecodeEngine> {
    Arc::new(DecodeEngine::new(weights()))
}

fn prompt(seed: u64, l: usize) -> Vec<i32> {
    let mut rng = Xoshiro256pp::new(seed);
    (0..l).map(|_| rng.below(64) as i32).collect()
}

fn pool_for(eng: &Arc<DecodeEngine>, block_size: usize, max_blocks: usize) -> PagedPool {
    PagedPool::new(block_size, max_blocks, eng.weights().cfg.d_head())
}

#[test]
fn paged_dense_decode_is_bit_identical_to_contiguous_per_step() {
    // block size 4 forces the 28-token context across many blocks, so
    // every boundary (fill, new-block allocation) is crossed mid-run
    let eng = engine();
    let pool = pool_for(&eng, 4, 256);
    let seq = prompt(21, 28);
    let mut contiguous = DecodeState::new(Arc::clone(&eng), DecodeConfig::default());
    let mut paged = PagedDecodeState::new(Arc::clone(&eng), DecodeConfig::default(), &pool);
    for (t, &tok) in seq.iter().enumerate() {
        let want = contiguous.push(tok);
        let got = paged.push(tok);
        assert_eq!(got, want, "paged dense logits diverged at step {t}");
    }
    assert!(pool.stats().peak > 8, "a 28-token context must span multiple blocks per chain");
}

#[test]
fn paged_spls_evicting_decode_is_bit_identical_to_contiguous_per_step() {
    // all blocks are private (no prefix shared), so SpAtten-style score
    // eviction must pick the same victims in the same order as the
    // contiguous cache — greedy continuations stay bitwise equal too
    let eng = engine();
    let pool = pool_for(&eng, 4, 512);
    let cfg = DecodeConfig {
        mode: DecodeMode::Spls,
        kv_budget: 16,
        recent: 4,
        spls: SplsConfig::default(),
    };
    let p = prompt(22, 24);
    let mut contiguous = DecodeState::new(Arc::clone(&eng), cfg);
    let mut paged = PagedDecodeState::new(Arc::clone(&eng), cfg, &pool);
    let mut last = {
        let want = contiguous.push(p[0]);
        let got = paged.push(p[0]);
        assert_eq!(got, want, "paged evicting logits diverged at prompt step 0");
        want
    };
    for (t, &tok) in p.iter().enumerate().skip(1) {
        let want = contiguous.push(tok);
        let got = paged.push(tok);
        assert_eq!(got, want, "paged evicting logits diverged at prompt step {t}");
        last = want;
    }
    // the logits matched bitwise, so both sides see the same greedy token
    for t in 0..16 {
        let next = argmax(&last) as i32;
        let want = contiguous.push(next);
        let got = paged.push(next);
        assert_eq!(got, want, "paged evicting logits diverged at decode step {t}");
        last = want;
    }
    let stats = paged.stats();
    assert!(stats.evictions > 0, "39 cached tokens into 16 slots must evict");
}

#[test]
fn attached_session_replays_the_cold_stream_and_sharing_saves_blocks() {
    let eng = engine();
    let p = prompt(23, 20);
    let (prefix, tail) = p.split_at(16);
    let max_new = 12usize;
    let cfg = DecodeConfig::default();

    // contiguous reference for the whole prompt
    let mut reference = GenSession::new(Arc::clone(&eng), cfg, p.clone(), max_new, Sampling::Greedy);
    while !reference.done() {
        reference.run_steps(8);
    }

    // cold paged session publishes the prefix; a replay attaches to it
    let pool = pool_for(&eng, 8, 512);
    let run = |expect_attach: bool| {
        let mut s = GenSession::new_paged(
            Arc::clone(&eng),
            cfg,
            &pool,
            prefix,
            tail.to_vec(),
            max_new,
            Sampling::Greedy,
        );
        assert_eq!(s.attached_prefix(), expect_attach);
        while !s.done() {
            s.run_steps(8);
        }
        (s.generated().to_vec(), s.stats().steps)
    };
    let (cold, cold_steps) = run(false);
    let (warm, warm_steps) = run(true);
    assert_eq!(cold, reference.generated(), "paged stream diverged from contiguous");
    assert_eq!(warm, cold, "attached session diverged from the cold one");
    assert_eq!(
        warm_steps + prefix.len(),
        cold_steps,
        "attaching must skip exactly the shared prefix's pushes"
    );
    let stats = pool.stats();
    assert_eq!(stats.prefix_hits, 1);
    assert!(stats.shared_attach_tokens >= prefix.len());

    // sharing a prefix across a wave must peak at strictly fewer
    // blocks than the same wave declaring private per-session prefixes
    let wave_peak = |private: bool| {
        let pool = pool_for(&eng, 8, 1024);
        let mut sessions: Vec<GenSession> = Vec::new();
        for i in 0..4usize {
            let mut pre = prefix.to_vec();
            if private {
                pre[0] = i as i32; // pairwise distinct: nothing attaches
            }
            let mut s = GenSession::new_paged(
                Arc::clone(&eng),
                cfg,
                &pool,
                &pre,
                tail.to_vec(),
                max_new,
                Sampling::Greedy,
            );
            if i == 0 {
                s.run_steps(pre.len()); // publish before the others admit
            }
            sessions.push(s);
        }
        loop {
            let mut live = false;
            for s in sessions.iter_mut() {
                if !s.done() {
                    live = true;
                    s.run_steps(4);
                }
            }
            if !live {
                break;
            }
        }
        pool.stats().peak
    };
    let shared_peak = wave_peak(false);
    let private_peak = wave_peak(true);
    assert!(
        shared_peak < private_peak,
        "prefix sharing must allocate strictly fewer blocks \
         (shared peak {shared_peak} vs private peak {private_peak})"
    );
}

#[test]
fn distinct_decode_configs_share_independently() {
    // trie entries are keyed on (tokens, DecodeConfig): a prefix
    // published under the dense rule must not serve an SPLS session
    // (its KV was computed under a different masking rule), and each
    // config publishes and replays its own snapshot bit-identically
    let eng = engine();
    let p = prompt(29, 20);
    let (prefix, tail) = p.split_at(16);
    let max_new = 8usize;
    let dense = DecodeConfig::default();
    let spls = DecodeConfig {
        mode: DecodeMode::Spls,
        kv_budget: 64, // larger than the run: masking differs, eviction never kicks in
        recent: 4,
        spls: SplsConfig::default(),
    };
    let reference = |cfg: DecodeConfig| {
        let mut s = GenSession::new(Arc::clone(&eng), cfg, p.clone(), max_new, Sampling::Greedy);
        while !s.done() {
            s.run_steps(8);
        }
        s.generated().to_vec()
    };
    let dense_want = reference(dense);
    let spls_want = reference(spls);

    let pool = pool_for(&eng, 8, 1024);
    let run = |cfg: DecodeConfig, expect_attach: bool| {
        let mut s = GenSession::new_paged(
            Arc::clone(&eng),
            cfg,
            &pool,
            prefix,
            tail.to_vec(),
            max_new,
            Sampling::Greedy,
        );
        assert_eq!(s.attached_prefix(), expect_attach);
        while !s.done() {
            s.run_steps(8);
        }
        s.generated().to_vec()
    };
    // dense publishes first; the spls session misses (config differs)
    // and publishes its own entry for the same tokens
    assert_eq!(run(dense, false), dense_want);
    assert_eq!(run(spls, false), spls_want);
    // replays attach to their own config's entry, bit-identically
    assert_eq!(run(dense, true), dense_want);
    assert_eq!(run(spls, true), spls_want);
    let stats = pool.stats();
    assert_eq!(stats.prefix_hits, 2, "one hit per config replay: {stats:?}");
    assert_eq!(stats.trie_entries, 2, "each config owns its own entry: {stats:?}");
}

#[test]
#[should_panic(expected = "set the mask generator before declaring a prefix")]
fn mask_gen_after_prefix_is_refused() {
    // `.with_prefix(p).with_mask_gen(g)` would attach (or declare for
    // publishing) KV computed under the default SPLS rule and then
    // decode with the custom mask — silently wrong logits. The builder
    // refuses the ordering outright.
    let eng = engine();
    let pool = pool_for(&eng, 8, 64);
    let cfg = DecodeConfig {
        mode: DecodeMode::Spls,
        kv_budget: 64,
        recent: 4,
        spls: SplsConfig::default(),
    };
    let pfx = prompt(31, 8);
    let _ = PagedDecodeState::new(Arc::clone(&eng), cfg, &pool)
        .with_prefix(&pfx)
        .with_mask_gen(Arc::new(esact::spls::maskgen::ThreeComponent::default()));
}
