//! Decode-engine correctness: the headline contract is that with an
//! unbounded KV budget, greedy `generate` output is **bit-identical**
//! to repeatedly re-running the full causal prefill forward on the
//! growing sequence — the no-eviction decode path is a pure refactor
//! of prefill. Plus: eviction respects budgets, the Spls machinery
//! with everything gated off equals the dense path, and streaming
//! serve_generate matches offline decode.

use std::sync::Arc;

use esact::config::SplsConfig;
use esact::decode::{
    generate, DecodeConfig, DecodeEngine, DecodeMode, DecodeState, GenSession, Sampling,
};
use esact::model::tensor::argmax;
use esact::model::{next_token_logits, TinyWeights};
use esact::spls::SharedPlanCache;
use esact::util::rng::Xoshiro256pp;

fn weights() -> Arc<TinyWeights> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny_weights.bin");
    Arc::new(TinyWeights::load(&p).unwrap())
}

fn engine() -> Arc<DecodeEngine> {
    Arc::new(DecodeEngine::new(weights()))
}

fn prompt(seed: u64, l: usize) -> Vec<i32> {
    let mut rng = Xoshiro256pp::new(seed);
    (0..l).map(|_| rng.below(64) as i32).collect()
}

#[test]
fn unbounded_greedy_decode_is_bit_identical_to_iterated_prefill() {
    let w = weights();
    let eng = Arc::new(DecodeEngine::new(Arc::clone(&w)));
    let p = prompt(1, 16);
    let max_new = 16usize;

    // reference: re-run the full causal prefill on the growing sequence
    let mut seq = p.clone();
    let mut want = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        let logits = next_token_logits(&w, &seq);
        let t = argmax(&logits) as i32;
        want.push(t);
        seq.push(t);
    }

    let got = generate(&eng, DecodeConfig::default(), &p, max_new, Sampling::Greedy, |_, _| {});
    assert_eq!(got.tokens, want, "decode stream diverged from iterated prefill");
    assert_eq!(got.stats.evictions, 0, "unbounded budget must never evict");
}

#[test]
fn unbounded_decode_logits_are_bit_identical_at_every_step() {
    // stronger than token equality: the raw f32 logits match bitwise
    let w = weights();
    let eng = Arc::new(DecodeEngine::new(Arc::clone(&w)));
    let seq = prompt(2, 28);
    let mut st = DecodeState::new(eng, DecodeConfig::default());
    for t in 1..=seq.len() {
        let got = st.push(seq[t - 1]);
        let want = next_token_logits(&w, &seq[..t]);
        assert_eq!(got, want, "logits diverged at prefix length {t}");
    }
}

#[test]
fn spls_with_gating_disabled_equals_dense_decode_bitwise() {
    // top_k = 1 (keep all), sim_threshold < 0 (never similar),
    // ffn_threshold = MAX (never skip): the Spls pipeline runs its
    // prediction machinery but gates nothing — logits must equal the
    // dense path exactly, making the gated path a strict superset
    let eng = engine();
    let seq = prompt(3, 20);
    let spls =
        SplsConfig { top_k: 1.0, sim_threshold: -1.0, ffn_threshold: usize::MAX, window: 8 };
    let cfg = DecodeConfig { mode: DecodeMode::Spls, spls, ..DecodeConfig::default() };
    let mut a = DecodeState::new(Arc::clone(&eng), cfg);
    let mut b = DecodeState::new(eng, DecodeConfig::default());
    for &t in &seq {
        assert_eq!(a.push(t), b.push(t));
    }
}

#[test]
fn evicting_decode_respects_budget_and_stays_finite() {
    let eng = engine();
    let p = prompt(4, 32);
    let cfg = DecodeConfig {
        mode: DecodeMode::Spls,
        kv_budget: 16,
        recent: 4,
        spls: SplsConfig::default(),
    };
    let mut s = GenSession::new(Arc::clone(&eng), cfg, p, 32, Sampling::Greedy);
    while !s.done() {
        s.run_steps(8);
    }
    let stats = s.stats();
    assert!(stats.evictions > 0, "63 cached tokens into 16 slots must evict");
    assert_eq!(s.generated().len(), 32);
    assert!(s.generated().iter().all(|&t| (0..64).contains(&t)));
    assert!(s.last_logits().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn serve_generate_matches_offline_decode_and_streams_chunks() {
    use esact::coordinator::server::Mode;
    use esact::coordinator::{GenRequest, Server};
    use std::sync::mpsc;
    use std::time::Instant;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let srv = Server::new(&dir, Mode::Dense, SplsConfig::default()).unwrap();
    let eng = engine();
    let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt(10 + i, 12)).collect();
    let max_new = 10usize;
    let want: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| {
            generate(&eng, DecodeConfig::default(), p, max_new, Sampling::Greedy, |_, _| {})
                .tokens
        })
        .collect();

    let (tx, rx) = mpsc::channel();
    let (ctx, crx) = mpsc::channel();
    for (i, p) in prompts.iter().enumerate() {
        tx.send(GenRequest {
            id: i as u64,
            prompt: p.clone(),
            prefix: None,
            max_new,
            sampling: Sampling::Greedy,
            arrived: Instant::now(),
        })
        .unwrap();
    }
    drop(tx);
    let drain = std::thread::spawn(move || {
        let mut streams: Vec<Vec<i32>> = vec![Vec::new(); 4];
        let mut chunks = 0usize;
        for c in crx.iter() {
            chunks += 1;
            streams[c.id as usize].extend(&c.tokens);
        }
        (streams, chunks)
    });
    let outcome = srv.serve_generate(rx, ctx, DecodeConfig::default(), 2, 3).unwrap();
    let (streams, chunks) = drain.join().unwrap();
    for (got, want) in streams.iter().zip(&want) {
        assert_eq!(got, want, "replicated streaming changed a generation");
    }
    assert_eq!(outcome.metrics.tokens, 4 * max_new);
    assert!(
        chunks > 4,
        "slices of 3 steps must stream multiple chunks per session (got {chunks})"
    );
}

#[test]
fn step_plan_cache_makes_replay_deterministic_with_hits() {
    let eng = engine();
    let cache = SharedPlanCache::new(2048);
    let p = prompt(6, 24);
    let cfg = DecodeConfig {
        mode: DecodeMode::Spls,
        kv_budget: 16,
        recent: 4,
        spls: SplsConfig::default(),
    };
    let run = || {
        let mut s = GenSession::new(Arc::clone(&eng), cfg, p.clone(), 12, Sampling::Greedy)
            .with_plan_cache(cache.clone());
        while !s.done() {
            s.run_steps(16);
        }
        (s.generated().to_vec(), s.stats())
    };
    let (first, s1) = run();
    assert!(s1.plan_misses > 0 && s1.plan_hits == 0, "cold run computes: {s1:?}");
    let (second, s2) = run();
    assert_eq!(first, second, "cache hits changed the generated stream");
    assert!(s2.plan_hits > 0, "warm run must hit: {s2:?}");
    assert_eq!(s2.plan_misses, 0, "fully warm replay recomputes nothing: {s2:?}");
    assert!(cache.stats().step_hit_rate() > 0.0);
}
