//! Chaos integration: seeded fault injection kills replica workers
//! mid-run under classify and generate load, and the tier must survive
//! — no tier-level error, surviving streams bit-identical to a
//! fault-free run, faulted requests answered with typed in-band
//! `replica_fault` envelopes, and the degradation counters reconciling
//! exactly against the fault plan's trip counts.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use esact::config::SplsConfig;
use esact::coordinator::server::Mode;
use esact::coordinator::{
    BatchPolicy, Completion, GenRequest, Reply, Request, Server, StreamFault, Submission, Tier,
    TierConfig,
};
use esact::decode::{DecodeConfig, Sampling};
use esact::model;
use esact::util::fault::{FaultPlan, FaultSite};
use esact::util::rng::Xoshiro256pp;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn classify_requests(n: usize) -> Vec<Request> {
    let mut rng = Xoshiro256pp::new(911);
    (0..n)
        .map(|i| Request {
            id: i as u64,
            tokens: model::synth::gen_example(&mut rng, 64).0,
            arrived: Instant::now(),
        })
        .collect()
}

fn gen_requests(n: usize, max_new: usize) -> Vec<GenRequest> {
    let mut rng = Xoshiro256pp::new(77);
    (0..n)
        .map(|i| GenRequest {
            id: i as u64,
            prompt: model::synth::gen_example(&mut rng, 64).0[..12].to_vec(),
            prefix: None,
            max_new,
            sampling: Sampling::TopK { k: 4, temperature: 0.8, seed: 100 + i as u64 },
            arrived: Instant::now(),
        })
        .collect()
}

fn run_classify(srv: &Server, reqs: Vec<Request>, replicas: usize) -> (Vec<Reply>, esact::coordinator::ServeOutcome) {
    let (tx, rx) = mpsc::channel();
    let (rtx, rrx) = mpsc::channel();
    for r in reqs {
        tx.send(r).unwrap();
    }
    drop(tx);
    let collector = std::thread::spawn(move || {
        let mut replies: Vec<Reply> = rrx.iter().collect();
        replies.sort_by_key(|r| r.id);
        replies
    });
    let outcome = srv.serve_replicated(rx, rtx, BatchPolicy::default(), replicas).unwrap();
    (collector.join().unwrap(), outcome)
}

/// Drain one generate run: per-id concatenated tokens plus the typed
/// fault (if any) that ended each stream.
fn run_generate(
    srv: &Server,
    reqs: Vec<GenRequest>,
    replicas: usize,
) -> (HashMap<u64, (Vec<i32>, Option<StreamFault>)>, esact::coordinator::GenerateOutcome) {
    let n = reqs.len();
    let (tx, rx) = mpsc::channel();
    let (ctx, crx) = mpsc::channel();
    for r in reqs {
        tx.send(r).unwrap();
    }
    drop(tx);
    let collector = std::thread::spawn(move || {
        let mut streams: HashMap<u64, (Vec<i32>, Option<StreamFault>)> = HashMap::new();
        let mut done = 0usize;
        for c in crx.iter() {
            let entry = streams.entry(c.id).or_default();
            entry.0.extend(&c.tokens);
            if let Some(f) = c.fault {
                entry.1 = Some(f);
            }
            if c.done {
                done += 1;
            }
        }
        assert_eq!(done, n, "every stream must end with a done chunk");
        streams
    });
    let outcome = srv.serve_generate(rx, ctx, DecodeConfig::default(), replicas, 3).unwrap();
    (collector.join().unwrap(), outcome)
}

#[test]
fn classify_tier_survives_seeded_replica_panics() {
    let dir = artifacts();
    let n = 16usize;

    // fault-free reference: classify logits depend only on the tokens,
    // so every surviving reply must match these bit-for-bit
    let clean = Server::new(&dir, Mode::Dense, SplsConfig::default()).unwrap();
    let (want, _) = run_classify(&clean, classify_requests(n), 2);

    // two seeded panics: the very first classify execution and the
    // third — with ≥2 batches plus at least one retry, both explicit
    // triggers fire, so the expected trip count is exactly 2
    let plan = FaultPlan::seeded(7)
        .with_trigger(FaultSite::ClassifyJob, 0)
        .with_trigger(FaultSite::ClassifyJob, 2);
    let srv = Server::with_fault_plan(&dir, Mode::Dense, SplsConfig::default(), plan).unwrap();
    let (replies, outcome) = run_classify(&srv, classify_requests(n), 2);

    let trips = srv.fault_injector().unwrap().trips(FaultSite::ClassifyJob) as usize;
    assert_eq!(trips, 2, "both explicit triggers must fire exactly once");
    assert_eq!(replies.len(), n, "every request is answered — success or typed fault");

    let mut ok = 0usize;
    let mut faulted_replies = 0usize;
    for r in &replies {
        match &r.fault {
            None => {
                assert_eq!(
                    r.logits,
                    want[r.id as usize].logits,
                    "retried request {} diverged from the fault-free run",
                    r.id
                );
                ok += 1;
            }
            Some(f) => {
                assert_eq!(f.code, StreamFault::REPLICA_FAULT);
                assert!(r.logits.is_empty(), "faulted replies carry no logits");
                faulted_replies += 1;
            }
        }
    }
    assert_eq!(ok + faulted_replies, n);
    assert!(ok > 0, "most of the wave must survive two panics");

    // metrics reconcile exactly against the plan: one respawn per trip,
    // and each trip either retried the batch or (budget exhausted)
    // faulted it terminally
    assert_eq!(outcome.metrics.respawns, trips);
    assert_eq!(outcome.metrics.retried + outcome.metrics.faulted, trips);
    assert_eq!(outcome.metrics.requests, ok, "only successes count as served requests");
    assert_eq!(
        outcome.metrics.faulted == 0,
        faulted_replies == 0,
        "terminal faults and fault replies appear together"
    );
    assert_eq!(outcome.per_replica.len(), 2, "per-replica rows keep the tier shape");

    // the tier object survives for the next run: no poisoned state
    let (again, _) = run_classify(&srv, classify_requests(n), 2);
    assert_eq!(again.len(), n);
}

#[test]
fn faulted_decode_session_migrates_bit_identically() {
    let dir = artifacts();
    let max_new = 10usize;

    let clean = Server::new(&dir, Mode::Dense, SplsConfig::default()).unwrap();
    let (want, _) = run_generate(&clean, gen_requests(4, max_new), 2);

    // one seeded panic on the 4th decode slice: exactly one session
    // faults once, migrates (re-prefill + RNG fast-forward), finishes
    let plan = FaultPlan::seeded(3).with_trigger(FaultSite::DecodeJob, 3);
    let srv = Server::with_fault_plan(&dir, Mode::Dense, SplsConfig::default(), plan).unwrap();
    let (got, outcome) = run_generate(&srv, gen_requests(4, max_new), 2);

    let trips = srv.fault_injector().unwrap().trips(FaultSite::DecodeJob) as usize;
    assert_eq!(trips, 1, "the single explicit trigger fires exactly once");
    assert_eq!(got.len(), 4);
    for (id, (tokens, fault)) in &got {
        assert!(fault.is_none(), "first fault is within budget: no stream may abort");
        assert_eq!(
            tokens, &want[id].0,
            "migrated session {id} must continue bit-identically to the fault-free run"
        );
    }
    assert_eq!(outcome.metrics.migrated, 1);
    assert_eq!(outcome.metrics.faulted, 0);
    assert_eq!(outcome.metrics.aborted, 0);
    assert_eq!(outcome.metrics.respawns, 1);
    assert_eq!(outcome.metrics.sessions, 4);
    assert_eq!(outcome.metrics.tokens, 4 * max_new, "no token lost or duplicated");
}

#[test]
fn decode_session_aborts_in_band_after_retry_budget() {
    let dir = artifacts();
    // a single session on a single replica, panicking on its first two
    // slice executions: attempt 1 faults → migrate, attempt 2 faults →
    // terminal. The stream must end with the typed in-band abort while
    // the run itself completes cleanly.
    let plan = FaultPlan::seeded(5)
        .with_trigger(FaultSite::DecodeJob, 0)
        .with_trigger(FaultSite::DecodeJob, 1);
    let srv = Server::with_fault_plan(&dir, Mode::Dense, SplsConfig::default(), plan).unwrap();
    let (got, outcome) = run_generate(&srv, gen_requests(1, 8), 1);

    assert_eq!(srv.fault_injector().unwrap().trips(FaultSite::DecodeJob), 2);
    let (tokens, fault) = &got[&0];
    assert!(tokens.is_empty(), "both attempts died before emitting a token");
    let fault = fault.as_ref().expect("exhausted retry budget must abort in-band");
    assert_eq!(fault.code, StreamFault::REPLICA_FAULT);
    assert_eq!(outcome.metrics.migrated, 1, "first fault migrated");
    assert_eq!(outcome.metrics.faulted, 1, "second fault is terminal");
    assert_eq!(outcome.metrics.aborted, 1, "terminal fault counts as an aborted session");
    assert_eq!(outcome.metrics.respawns, 2, "the lone replica respawned after each panic");
    assert_eq!(outcome.metrics.tokens, 0);
}

#[test]
fn mixed_chaos_load_on_tier_handle_reconciles_metrics() {
    let dir = artifacts();
    let plan = FaultPlan::seeded(11)
        .with_trigger(FaultSite::ClassifyJob, 1)
        .with_trigger(FaultSite::DecodeJob, 2);
    let srv =
        Arc::new(Server::with_fault_plan(&dir, Mode::Dense, SplsConfig::default(), plan).unwrap());
    let tier = Tier::start(
        Arc::clone(&srv),
        TierConfig {
            policy: BatchPolicy::default(),
            decode: DecodeConfig::default(),
            replicas: 2,
            steps_per_slice: 2,
            max_sessions: 4,
            prefill_chunk: 0,
            trace_sample: 1,
        },
    )
    .unwrap();
    let handle = tier.handle();
    let (ntx, nrx) = mpsc::channel();
    handle.set_notify(move || {
        let _ = ntx.send(());
    });

    let classify = classify_requests(8);
    let mut batch: Vec<Submission> = classify
        .iter()
        .map(|r| Submission::Classify { tokens: r.tokens.clone() })
        .collect();
    for g in gen_requests(2, 6) {
        batch.push(Submission::Generate {
            prompt: g.prompt,
            prefix: None,
            max_new: 6,
            sampling: g.sampling,
        });
    }
    let total = batch.len();
    let ids = handle.submit(batch).unwrap();
    assert_eq!(ids.len(), total);

    let mut finished = 0usize;
    let mut fault_answers = 0usize;
    let mut completions = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(60);
    while finished < total {
        assert!(Instant::now() < deadline, "chaos tier stalled — a panic killed the tier");
        let _ = nrx.recv_timeout(Duration::from_millis(200));
        handle.take_completions(&mut completions);
        for c in completions.drain(..) {
            match c {
                Completion::Classify { logits, .. } => {
                    assert!(!logits.is_empty());
                    finished += 1;
                }
                Completion::ClassifyFailed { fault, .. } => {
                    assert_eq!(fault.code, StreamFault::REPLICA_FAULT);
                    fault_answers += 1;
                    finished += 1;
                }
                Completion::Generate { done, fault, .. } => {
                    if let Some(f) = &fault {
                        assert_eq!(f.code, StreamFault::REPLICA_FAULT);
                        fault_answers += 1;
                    }
                    if done {
                        finished += 1;
                    }
                }
            }
        }
    }
    assert!(handle.idle(), "every admission slot released under chaos");

    handle.close();
    let (serve, generate) = tier.join();
    let serve = serve.expect("classify lane must survive injected panics").metrics;
    let generate = generate.expect("generate lane must survive injected panics").metrics;

    let inj = srv.fault_injector().unwrap();
    let classify_trips = inj.trips(FaultSite::ClassifyJob) as usize;
    let decode_trips = inj.trips(FaultSite::DecodeJob) as usize;
    assert_eq!(classify_trips, 1);
    assert_eq!(decode_trips, 1);
    // every trip respawned exactly one worker, and every trip was
    // either recovered (retry / migration) or terminal — nothing is
    // double-counted and nothing vanishes
    assert_eq!(serve.respawns + generate.respawns, classify_trips + decode_trips);
    assert_eq!(serve.retried + serve.faulted, classify_trips);
    assert_eq!(generate.migrated + generate.faulted, decode_trips);
    // typed fault answers appear iff a fault was terminal (a terminal
    // classify fault answers every request of its batch, so the reply
    // count can exceed the batch count — never the reverse)
    assert_eq!(serve.faulted + generate.faulted == 0, fault_answers == 0);
    assert!(fault_answers >= serve.faulted + generate.faulted);

    // the live snapshot the gateway scrapes must agree with the joined
    // outcomes on the degradation counters
    let snap = srv.live_snapshot();
    assert_eq!(snap.serve.respawns, serve.respawns);
    assert_eq!(snap.generate.respawns, generate.respawns);
    assert_eq!(snap.serve.retried, serve.retried);
    assert_eq!(snap.generate.migrated, generate.migrated);

    // the fault path lands in trace spans with its retry lineage:
    // every submission completed a span, terminal faults carry a fault
    // code, recovered faults show up as extra attempts / migrations
    let spans = srv.obs().trace.recent(total);
    assert_eq!(spans.len(), total, "one completed span per submission");
    let faulted_spans = spans.iter().filter(|s| s.fault.is_some()).count();
    assert_eq!(faulted_spans, fault_answers, "typed fault answers and faulted spans agree");
    if serve.retried + generate.migrated > 0 {
        assert!(
            spans.iter().any(|s| s.attempts > 1),
            "recovered faults must leave attempt lineage in spans"
        );
    }
    let migrated_spans = spans.iter().filter(|s| s.migrated > 0).count();
    assert_eq!(migrated_spans, generate.migrated, "migrations land in spans");
}
