//! Parity and structure suite for the sparse-plan compiler
//! (`model::sparse_plan`) and the gather/CSR kernels it drives:
//!
//! * CSR lowering invariants over randomized ragged/band/full masks
//!   (offsets monotone, columns ascending, empty rows forbidden);
//! * a hostile all-false mask row fails **loudly** at plan lowering
//!   (the diagonal invariant) instead of silently zero-filling;
//! * compiled sparse execution is **bit-identical** to the unpacked
//!   `model::forward_sparse` on hand-built band/ragged/full plans and
//!   on randomized planned operating points;
//! * the packed masked path stays bit-identical to the unpacked
//!   `model::forward_masked` on random masks **including forced
//!   fully-masked rows** (the raw-mask zero-fill tolerance is pinned);
//! * cross-dataflow epsilon-corridor parity: with nothing gated,
//!   `forward_sparse` and `forward_masked` are the same math through
//!   different accumulation chains (bias-first per-head projection vs
//!   full-width matmul + bias-after), and must agree on the classifier
//!   logits within the documented [`PARITY_EPS`] bound.

use std::sync::Arc;

use esact::config::SplsConfig;
use esact::model::weights::LayerWeights;
use esact::model::{
    forward_masked, forward_sparse, plan_model, within_parity_corridor, CompiledModelPlan,
    PackedModel, TinyConfig, TinyWeights, PARITY_EPS,
};
use esact::quant::QuantMethod;
use esact::spls::mfi::FfnPlan;
use esact::spls::plan::{lower_mask_rows, LayerPlan};
use esact::spls::qkv::HeadPlan;
use esact::spls::similarity::SimilarityMap;
use esact::util::mat::{Mat, MatF};
use esact::util::rng::Xoshiro256pp;
use esact::util::scratch::Scratch;

fn rand_vec(rng: &mut Xoshiro256pp, n: usize, lo: f64, hi: f64) -> Vec<f32> {
    (0..n).map(|_| (lo + rng.f64() * (hi - lo)) as f32).collect()
}

fn rand_mat(rng: &mut Xoshiro256pp, r: usize, c: usize) -> MatF {
    MatF::from_vec(r, c, rand_vec(rng, r * c, -0.25, 0.25))
}

fn synth_weights(rng: &mut Xoshiro256pp, cfg: TinyConfig) -> TinyWeights {
    let (d, f) = (cfg.d_model, cfg.d_ffn);
    let layers = (0..cfg.n_layers)
        .map(|_| LayerWeights {
            wq: rand_mat(rng, d, d),
            bq: rand_vec(rng, d, -0.1, 0.1),
            wk: rand_mat(rng, d, d),
            bk: rand_vec(rng, d, -0.1, 0.1),
            wv: rand_mat(rng, d, d),
            bv: rand_vec(rng, d, -0.1, 0.1),
            wo: rand_mat(rng, d, d),
            bo: rand_vec(rng, d, -0.1, 0.1),
            ln1_g: rand_vec(rng, d, 0.8, 1.2),
            ln1_b: rand_vec(rng, d, -0.1, 0.1),
            w1: rand_mat(rng, d, f),
            b1: rand_vec(rng, f, -0.1, 0.1),
            w2: rand_mat(rng, f, d),
            b2: rand_vec(rng, d, -0.1, 0.1),
            ln2_g: rand_vec(rng, d, 0.8, 1.2),
            ln2_b: rand_vec(rng, d, -0.1, 0.1),
        })
        .collect();
    TinyWeights {
        embed: rand_mat(rng, cfg.vocab, d),
        pos: rand_mat(rng, cfg.seq_len, d),
        layers,
        lnf_g: rand_vec(rng, d, 0.8, 1.2),
        lnf_b: rand_vec(rng, d, -0.1, 0.1),
        cls_w: rand_mat(rng, d, cfg.n_classes),
        cls_b: rand_vec(rng, cfg.n_classes, -0.1, 0.1),
        cfg,
    }
}

fn small_cfg() -> TinyConfig {
    TinyConfig {
        vocab: 32,
        seq_len: 24,
        d_model: 24,
        n_heads: 3,
        n_layers: 2,
        d_ffn: 40,
        n_classes: 5,
    }
}

fn rand_tokens(rng: &mut Xoshiro256pp, l: usize, vocab: usize) -> Vec<i32> {
    (0..l).map(|_| rng.below(vocab as u64) as i32).collect()
}

/// A hand-built mask pattern over an L×L head.
enum Pattern {
    /// Keep |r − c| ≤ w.
    Band(usize),
    /// Keep everything.
    Full,
    /// Random ragged rows (diagonal always kept).
    Ragged,
}

fn build_mask(l: usize, p: &Pattern, rng: &mut Xoshiro256pp) -> Mat<bool> {
    match p {
        Pattern::Band(w) => Mat::from_fn(l, l, |r, c| r.abs_diff(c) <= *w),
        Pattern::Full => Mat::from_fn(l, l, |_, _| true),
        Pattern::Ragged => {
            let mut m = Mat::from_fn(l, l, |_, _| rng.f64() < 0.3);
            for r in 0..l {
                m[(r, r)] = true; // diagonal invariant
            }
            m
        }
    }
}

/// Identity similarity (every row critical) or even-pairs similarity
/// (odd rows recover from the even row below them, window 2).
fn sim_map(l: usize, pairs: bool) -> SimilarityMap {
    let rep = (0..l).map(|r| if pairs { r - (r % 2) } else { r }).collect();
    SimilarityMap { rep, window: 2 }
}

fn hand_built_plans(cfg: &TinyConfig, pattern: Pattern, pairs: bool, seed: u64) -> Vec<LayerPlan> {
    let l = cfg.seq_len;
    let mut rng = Xoshiro256pp::new(seed);
    (0..cfg.n_layers)
        .map(|_| {
            let heads = (0..cfg.n_heads)
                .map(|_| HeadPlan::new(build_mask(l, &pattern, &mut rng), sim_map(l, pairs)))
                .collect();
            LayerPlan { heads, ffn: FfnPlan { rep: sim_map(l, pairs).rep } }
        })
        .collect()
}

#[test]
fn csr_lowering_invariants_over_randomized_masks() {
    let mut rng = Xoshiro256pp::new(0xc5a);
    for l in [4usize, 9, 17, 32] {
        for pattern in [Pattern::Band(2), Pattern::Full, Pattern::Ragged] {
            let mask = build_mask(l, &pattern, &mut rng);
            // a random ascending subset of rows (always non-empty)
            let rows: Vec<usize> = (0..l).filter(|&r| r == 0 || rng.f64() < 0.6).collect();
            let csr = lower_mask_rows(&mask, &rows, true);
            assert_eq!(csr.row_offsets.len(), rows.len() + 1);
            assert_eq!(csr.row_offsets[0], 0);
            assert_eq!(*csr.row_offsets.last().unwrap() as usize, csr.nnz());
            for (i, &r) in rows.iter().enumerate() {
                let (b, e) = (csr.row_offsets[i] as usize, csr.row_offsets[i + 1] as usize);
                assert!(e > b, "empty CSR row slipped through");
                let cols = &csr.col_indices[b..e];
                assert!(cols.windows(2).all(|w| w[0] < w[1]), "columns not ascending");
                let want: Vec<u32> = (0..l as u32).filter(|&c| mask[(r, c as usize)]).collect();
                assert_eq!(cols, &want[..], "row {r} columns diverge from mask");
            }
        }
    }
}

#[test]
#[should_panic(expected = "diagonal invariant")]
fn hostile_all_false_row_fails_loudly_not_silently() {
    // the bug this guards: masked_softmax_row silently zero-fills a
    // fully-masked row; a compiled plan must refuse such a row instead
    let mut mask = Mat::from_fn(8, 8, |r, c| r == c);
    for c in 0..8 {
        mask[(3, c)] = false; // hostile: row 3 keeps nothing
    }
    let _ = lower_mask_rows(&mask, &(0..8).collect::<Vec<_>>(), true);
}

#[test]
fn compiled_sparse_bit_identical_on_hand_built_patterns() {
    let mut rng = Xoshiro256pp::new(0xbead);
    let cfg = small_cfg();
    let w = Arc::new(synth_weights(&mut rng, cfg));
    let pm = PackedModel::new(Arc::clone(&w));
    let mut sc = Scratch::new();
    let toks = rand_tokens(&mut rng, cfg.seq_len, cfg.vocab);
    for (pattern, pairs, seed) in [
        (Pattern::Band(2), false, 11u64),
        (Pattern::Band(4), true, 12),
        (Pattern::Full, false, 13),
        (Pattern::Full, true, 14),
        (Pattern::Ragged, false, 15),
        (Pattern::Ragged, true, 16),
    ] {
        let plans = hand_built_plans(&cfg, pattern, pairs, seed);
        // explicit two-step form: lower once, execute the compiled plan
        let compiled = CompiledModelPlan::lower(&plans);
        let got = pm.forward_sparse_compiled(&toks, &compiled, &mut sc);
        let want = forward_sparse(&w, &toks, &plans);
        assert_eq!(got, want, "compiled sparse diverged (pairs = {pairs})");
        // the wrapper (lower + execute) must agree with itself too
        assert_eq!(pm.forward_sparse(&toks, &plans, &mut sc), want);
    }
}

#[test]
fn packed_masked_zero_fill_tolerance_is_pinned_bitwise() {
    // random external f32 masks with rows FORCED fully-masked: the
    // raw-mask path must keep the documented zero-fill semantics and
    // stay bit-identical to the unpacked reference (only plan-lowered
    // execution rejects empty rows)
    let mut rng = Xoshiro256pp::new(0x0f11);
    let cfg = small_cfg();
    let w = Arc::new(synth_weights(&mut rng, cfg));
    let pm = PackedModel::new(Arc::clone(&w));
    let mut sc = Scratch::new();
    for trial in 0..4 {
        let l = 3 + rng.below((cfg.seq_len - 3) as u64) as usize;
        let toks = rand_tokens(&mut rng, l, cfg.vocab);
        let mut masks: Vec<f32> = (0..cfg.n_layers * cfg.n_heads * l * l)
            .map(|_| if rng.f64() < 0.4 { 0.0 } else { 1.0 })
            .collect();
        // force at least one fully-masked row per head
        for head in 0..cfg.n_layers * cfg.n_heads {
            let r = rng.below(l as u64) as usize;
            let base = head * l * l + r * l;
            masks[base..base + l].fill(0.0);
        }
        assert_eq!(
            pm.forward_masked(&toks, &masks, &mut sc),
            forward_masked(&w, &toks, &masks),
            "masked path diverged on trial {trial} (L = {l})"
        );
    }
}

#[test]
fn compiled_sparse_bit_identical_on_randomized_planned_points() {
    // real planner output (band-ish SPA masks, similarity collapse,
    // MFI-gated FFN) across random operating points — the compiled
    // CSR execution must not change a bit of the unpacked reference
    let mut rng = Xoshiro256pp::new(0x9e0);
    let cfg = small_cfg();
    let w = Arc::new(synth_weights(&mut rng, cfg));
    let pm = PackedModel::new(Arc::clone(&w));
    let mut sc = Scratch::new();
    for _ in 0..6 {
        let l = 4 + rng.below((cfg.seq_len - 4) as u64) as usize;
        let toks = rand_tokens(&mut rng, l, cfg.vocab);
        let spls = SplsConfig {
            top_k: (0.05 + rng.f64() * 0.9) as f32,
            sim_threshold: (rng.f64() * 1.2) as f32,
            ffn_threshold: 1 + rng.below(3) as usize,
            window: 2 + rng.below(8) as usize,
        };
        let plans = plan_model(&w, &toks, &spls, QuantMethod::Hlog);
        assert_eq!(
            pm.forward_sparse(&toks, &plans, &mut sc),
            forward_sparse(&w, &toks, &plans),
            "compiled sparse diverged at {spls:?} L {l}"
        );
    }
}

#[test]
fn sparse_vs_masked_cross_dataflow_within_epsilon_corridor() {
    // nothing gated: similarity off (identity rep), FFN skipping off —
    // forward_sparse and forward_masked then compute the same math
    // through different accumulation chains (bias-first per-head Q/K/V
    // projection vs full-width matmul with bias after). The logits must
    // agree within the documented reassociation corridor; bitwise
    // equality is NOT expected here, which is exactly why the corridor
    // mode exists alongside the bitwise suites.
    let mut rng = Xoshiro256pp::new(0xe95);
    let cfg = small_cfg();
    let w = Arc::new(synth_weights(&mut rng, cfg));
    let pm = PackedModel::new(Arc::clone(&w));
    let mut sc = Scratch::new();
    for trial in 0..4 {
        let l = cfg.seq_len;
        let toks = rand_tokens(&mut rng, l, cfg.vocab);
        let spls = SplsConfig {
            top_k: (0.2 + rng.f64() * 0.8) as f32,
            sim_threshold: -1.0,          // no row collapses
            ffn_threshold: usize::MAX,    // no FFN skips
            window: 4,
        };
        let plans = plan_model(&w, &toks, &spls, QuantMethod::Hlog);
        for plan in &plans {
            for head in &plan.heads {
                assert!(head.sim.critical_rows().len() == l, "identity sim expected");
            }
        }
        // expand the plan masks to the [n_layers, n_heads, L, L] f32
        // form the masked program consumes (rep is identity here)
        let mut masks = Vec::with_capacity(cfg.n_layers * cfg.n_heads * l * l);
        for plan in &plans {
            for head in &plan.heads {
                for r in 0..l {
                    for c in 0..l {
                        masks.push(if head.mask[(r, c)] { 1.0f32 } else { 0.0 });
                    }
                }
            }
        }
        let sparse = pm.forward_sparse(&toks, &plans, &mut sc);
        let masked = pm.forward_masked(&toks, &masks, &mut sc);
        assert!(
            within_parity_corridor(&sparse, &masked, PARITY_EPS),
            "trial {trial}: cross-dataflow drift exceeds {PARITY_EPS}: \
             sparse {sparse:?} vs masked {masked:?}"
        );
    }
}
