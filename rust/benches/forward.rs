//! Forward-path benchmarks: prefill tokens/sec of the **unpacked
//! reference** (`model::transformer`, per-call head slicing, serial
//! kernels) vs the **packed execution engine** (`model::engine`:
//! pre-packed operands, scratch-arena reuse, row-parallel
//! autovectorized kernels) across {dense, masked, sparse} × sequence
//! length. The two are bit-identical (`tests/packed_parity.rs`), so
//! every speedup cell is a pure execution-engine win.
//!
//! A second cell group sweeps the **sparse-vs-dense crossover**: the
//! compiled CSR/gather sparse path (`forward_sparse_compiled`) against
//! the packed dense path at L = 64 across SPLS operating points, with
//! the *measured* keep-density (fraction of dense FLOPs the plan
//! keeps, `spls::keep_density`) on the x-axis. Past the documented
//! sparsity level the sparse path must win — the inversion this bench
//! exists to keep dead.
//!
//! Emits the machine-readable `BENCH_4.json` report (set
//! `ESACT_BENCH_JSON`) that `scripts/bench_gate.py` gates against the
//! committed `bench_baseline.json`: absolute packed-throughput floors
//! per cell, the headline packed-must-beat-unpacked inversion check at
//! seq-len ≥ 64, and the crossover Spls-beats-Dense check below the
//! baseline's keep-density threshold (both warn-only on single-core
//! runners, where the row-parallel kernels have nothing to fan out
//! over).

use std::fmt::Write as _;
use std::hint::black_box;
use std::sync::Arc;
use std::time::Instant;

use esact::config::{ModelConfig, SplsConfig};
use esact::model::{
    forward_dense, forward_masked, forward_sparse, plan_model, CompiledModelPlan, PackedModel,
    TinyWeights,
};
use esact::quant::QuantMethod;
use esact::spls::plan::{keep_density, LayerPlan};
use esact::util::rng::Xoshiro256pp;
use esact::util::scratch::Scratch;

const REPS: usize = 5;
const ITERS: usize = 8;

struct Cell {
    path: &'static str,
    seq_len: usize,
    unpacked_tps: f64,
    packed_tps: f64,
}

impl Cell {
    fn speedup(&self) -> f64 {
        self.packed_tps / self.unpacked_tps.max(1e-12)
    }

    fn print(&self) {
        println!(
            "  {:<6} L {:>3}: unpacked {:>9.0} tok/s | packed {:>9.0} tok/s | {:>5.2}x",
            self.path,
            self.seq_len,
            self.unpacked_tps,
            self.packed_tps,
            self.speedup()
        );
    }

    fn json(&self) -> String {
        format!(
            "{{\"path\": \"{}\", \"seq_len\": {}, \"unpacked_tps\": {:.2}, \
             \"packed_tps\": {:.2}, \"speedup\": {:.4}}}",
            self.path,
            self.seq_len,
            self.unpacked_tps,
            self.packed_tps,
            self.speedup()
        )
    }
}

/// Best-of-REPS prefill throughput of `f`, in tokens/sec for an
/// `l`-token sequence (one warmup call sizes arenas and caches).
fn best_tps(l: usize, mut f: impl FnMut()) -> f64 {
    f();
    let mut best = 0.0f64;
    for _ in 0..REPS {
        let t0 = Instant::now();
        for _ in 0..ITERS {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        best = best.max((l * ITERS) as f64 / dt.max(1e-12));
    }
    best
}

/// Rep-expanded `[n_layers, n_heads, L, L]` f32 masks (similar rows
/// carry their critical row's mask) for the masked bench cells. The
/// serving tier no longer executes this expansion — Spls requests run
/// the compiled CSR/gather plans directly — but the masked program
/// remains a benched path (AOT parity surface, external-mask API).
fn expand_masks(plans: &[LayerPlan], l: usize) -> Vec<f32> {
    let mut out = Vec::new();
    for plan in plans {
        for head in &plan.heads {
            for r in 0..l {
                let src = head.sim.rep[r];
                for c in 0..l {
                    out.push(if head.mask[(src, c)] { 1.0 } else { 0.0 });
                }
            }
        }
    }
    out
}

fn main() -> anyhow::Result<()> {
    let dir = esact::util::artifacts_dir();
    let weights = Arc::new(TinyWeights::load(&dir.join("tiny_weights.bin"))?);
    let pm = Arc::new(PackedModel::new(Arc::clone(&weights)));
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut rng = Xoshiro256pp::new(17);
    let mut sc = Scratch::new();
    let mut cells: Vec<Cell> = Vec::new();
    let spls = SplsConfig::default();

    println!("== prefill throughput: packed engine vs unpacked reference ({cores} cores) ==");
    for l in [16usize, 32, 64] {
        let toks: Vec<i32> = (0..l).map(|_| rng.below(64) as i32).collect();
        let plans = plan_model(&weights, &toks, &spls, QuantMethod::Hlog);
        let masks = expand_masks(&plans, l);

        let unpacked = best_tps(l, || {
            black_box(forward_dense(&weights, &toks));
        });
        let packed = best_tps(l, || {
            black_box(pm.forward_dense(&toks, &mut sc));
        });
        cells.push(Cell { path: "dense", seq_len: l, unpacked_tps: unpacked, packed_tps: packed });

        let unpacked = best_tps(l, || {
            black_box(forward_masked(&weights, &toks, &masks));
        });
        let packed = best_tps(l, || {
            black_box(pm.forward_masked(&toks, &masks, &mut sc));
        });
        cells.push(Cell { path: "masked", seq_len: l, unpacked_tps: unpacked, packed_tps: packed });

        let unpacked = best_tps(l, || {
            black_box(forward_sparse(&weights, &toks, &plans));
        });
        let packed = best_tps(l, || {
            black_box(pm.forward_sparse(&toks, &plans, &mut sc));
        });
        cells.push(Cell { path: "sparse", seq_len: l, unpacked_tps: unpacked, packed_tps: packed });
    }
    for cell in &cells {
        cell.print();
    }
    for cell in cells.iter().filter(|c| c.seq_len >= 64) {
        let verdict = if cell.speedup() >= 1.5 {
            "hits the 1.5x target ✓"
        } else if cell.speedup() > 1.0 {
            "wins, below target"
        } else {
            "LOSES ✗"
        };
        println!(
            "  packed/unpacked @ {} L {}: {:.2}x ({verdict})",
            cell.path,
            cell.seq_len,
            cell.speedup()
        );
    }

    // --- sparse-vs-dense crossover: keep-density on the x-axis -------
    // Three operating points from "nothing pruned" to "aggressive";
    // plans AND lowered CSR/gather programs are built once, outside the
    // timed region (serving amortizes both through the plan cache).
    let xl = 64usize;
    let xtoks: Vec<i32> = (0..xl).map(|_| rng.below(64) as i32).collect();
    let cfg = weights.cfg;
    let mcfg = ModelConfig::new(
        "tiny", xl, cfg.d_model, cfg.n_heads, cfg.n_layers, cfg.d_ffn, false,
    );
    let dense_tps = best_tps(xl, || {
        black_box(pm.forward_dense(&xtoks, &mut sc));
    });
    let points = [
        ("open", SplsConfig {
            top_k: 1.0,
            sim_threshold: -1.0,
            ffn_threshold: usize::MAX,
            window: 8,
        }),
        ("default", SplsConfig::default()),
        ("aggressive", SplsConfig {
            top_k: 0.08,
            sim_threshold: 0.9,
            ffn_threshold: 1,
            window: 8,
        }),
    ];
    println!("== sparse-vs-dense crossover @ L {xl} (dense {dense_tps:.0} tok/s) ==");
    let mut xrows: Vec<String> = Vec::new();
    for (op, spls) in &points {
        let plans = plan_model(&weights, &xtoks, spls, QuantMethod::Hlog);
        let kd = keep_density(&mcfg, &plans);
        let compiled = CompiledModelPlan::lower(&plans);
        let sparse_tps = best_tps(xl, || {
            black_box(pm.forward_sparse_compiled(&xtoks, &compiled, &mut sc));
        });
        let speedup = sparse_tps / dense_tps.max(1e-12);
        println!(
            "  {op:<10} keep-density {kd:.3}: sparse {sparse_tps:>9.0} tok/s | {speedup:>5.2}x dense"
        );
        xrows.push(format!(
            "{{\"op\": \"{op}\", \"keep_density\": {kd:.4}, \"sparse_tps\": {sparse_tps:.2}, \
             \"dense_tps\": {dense_tps:.2}, \"speedup\": {speedup:.4}}}"
        ));
    }

    // --- machine-readable report for the CI regression gate ----------
    if let Ok(path) = std::env::var("ESACT_BENCH_JSON") {
        let rows = cells.iter().map(Cell::json).collect::<Vec<_>>().join(",\n    ");
        let mut out = String::from("{\n  \"schema\": 4,\n");
        let _ = writeln!(out, "  \"cores\": {cores},");
        let _ = writeln!(out, "  \"forward\": [\n    {rows}\n  ],");
        let _ = writeln!(out, "  \"crossover\": [\n    {}\n  ]", xrows.join(",\n    "));
        out.push_str("}\n");
        std::fs::write(&path, out)?;
        println!("\nwrote {path}");
    }
    Ok(())
}
