//! Micro-benchmarks of the L3 hot paths, criterion-style (the criterion
//! crate is not in the vendored set; `util::bench::Criterion` provides
//! the same `bench_function` / `Bencher::iter` surface with warmup +
//! percentile reporting). These are the L3 kernel measurement points
//! (DESIGN.md §Host kernel layout), plus the single-thread vs rayon
//! comparison for the parallelized SPLS→simulator hot path.

use esact::config::{self, HardwareConfig, SplsConfig};
use esact::model::tensor;
use esact::quant;
use esact::sim::{simulate_model, Features};
use esact::spls;
use esact::util::bench::{black_box, Criterion};
use esact::util::mat::{MatF, MatI};
use esact::util::rng::Xoshiro256pp;
use esact::workloads::bench26::SparsityProfile;

fn main() {
    let mut c = Criterion::new().sampling(10, 3);
    let mut rng = Xoshiro256pp::new(99);
    let l = 128usize;
    let d = 768usize;
    let dh = 64usize;

    // --- bit-level prediction unit ---------------------------------
    let x = MatI::from_fn(l, d, |_, _| rng.int_in(-128, 127) as i32);
    let wq = MatI::from_fn(d, dh, |_, _| rng.int_in(-128, 127) as i32);
    c.bench_function("predict_matmul 128x768x64", |b| {
        b.iter(|| spls::predict_matmul(&x, &wq))
    });
    c.bench_function("predict_attention 128x768 head", |b| {
        b.iter(|| spls::predict_attention(&x, &wq, &wq))
    });

    let xs: Vec<i32> = (0..(1 << 16)).map(|_| rng.int_in(-128, 127) as i32).collect();
    c.bench_function("hlog_quantize 64k", |b| {
        b.iter(|| {
            let mut acc = 0i64;
            for &v in &xs {
                acc += quant::hlog_quantize(v) as i64;
            }
            acc
        })
    });

    // --- SPA pipeline ------------------------------------------------
    let pam = MatI::from_fn(l, l, |r, c| ((r / 2 * 31 + c * 7) % 97) as i32);
    c.bench_function("topk sparsify 128x128", |b| b.iter(|| spls::sparsify(&pam, 0.12)));

    let (spa, _) = spls::sparsify(&pam, 0.12);
    c.bench_function("local_similarity w=8", |b| {
        b.iter(|| spls::local_similarity(&spa, 8, 0.6))
    });

    let spls_cfg = SplsConfig::default();
    let pams: Vec<MatI> = (0..12)
        .map(|h| MatI::from_fn(l, l, |r, c| ((r / 2 * 31 + c * 7 + h * 13) % 97) as i32))
        .collect();
    // plan_layer itself is measured in the 1-thread-vs-rayon section below

    // --- host tensor ops --------------------------------------------
    let a = MatF::from_fn(l, d, |_, _| rng.normal());
    let bm = MatF::from_fn(d, d, |_, _| rng.normal());
    c.bench_function("host matmul 128x768x768", |b| b.iter(|| tensor::matmul(&a, &bm)));

    let mut soft = MatF::from_fn(l, l, |_, _| rng.normal());
    c.bench_function("softmax_rows 128x128", |b| {
        b.iter(|| {
            tensor::softmax_rows(&mut soft);
        })
    });

    // --- single-thread vs rayon: the parallelized hot path -----------
    println!("\n== single-thread vs rayon (the tentpole comparison) ==");
    let hw = HardwareConfig::default();
    let profile = SparsityProfile { q: 0.6, kv: 0.6, attn: 0.946, ffn: 0.5 };
    let model = config::bert_large(512);
    let single = rayon::ThreadPoolBuilder::new()
        .num_threads(1)
        .build()
        .expect("single-thread pool");

    let mut c = Criterion::new().sampling(10, 3);
    let s1 = c.bench_function("simulate_model BERT-Large/512 (1 thread)", |b| {
        b.iter(|| single.install(|| simulate_model(&model, &hw, &spls_cfg, &profile, Features::FULL)))
    });
    let sn = c.bench_function("simulate_model BERT-Large/512 (rayon)", |b| {
        b.iter(|| simulate_model(&model, &hw, &spls_cfg, &profile, Features::FULL))
    });
    println!(
        "simulate_model speedup: {:.2}x on {} cores",
        s1.mean / sn.mean,
        rayon::current_num_threads()
    );

    let p1 = c.bench_function("plan_layer 12 heads (1 thread)", |b| {
        b.iter(|| single.install(|| spls::plan_layer(&pams, &spls_cfg)))
    });
    let pn = c.bench_function("plan_layer 12 heads (rayon)", |b| {
        b.iter(|| spls::plan_layer(&pams, &spls_cfg))
    });
    println!("plan_layer speedup: {:.2}x", p1.mean / pn.mean);

    let q1 = c.bench_function("predict_attention (1 thread)", |b| {
        b.iter(|| single.install(|| spls::predict_attention(&x, &wq, &wq)))
    });
    let qn = c.bench_function("predict_attention (rayon)", |b| {
        b.iter(|| spls::predict_attention(&x, &wq, &wq))
    });
    println!("predict_attention speedup: {:.2}x", q1.mean / qn.mean);

    // keep the optimizer honest about the data we bench on
    black_box((&x, &wq, &pam, &spa, &a));
}
