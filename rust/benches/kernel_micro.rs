//! Micro-benchmarks of the L3 hot paths (criterion is not in the
//! vendored set; `util::stats::bench` provides warmup + percentile
//! reporting). These are the §Perf measurement points in
//! EXPERIMENTS.md.

use esact::config::SplsConfig;
use esact::model::tensor;
use esact::quant;
use esact::spls;
use esact::util::mat::{MatF, MatI};
use esact::util::rng::Xoshiro256pp;
use esact::util::stats::bench;

fn report(name: &str, work: f64, s: esact::util::stats::Summary) {
    println!(
        "{name:<34} {:>10.1} µs/iter (p50 {:>8.1}, p95 {:>8.1}) {:>10.1} Mops/s",
        s.mean * 1e6,
        s.p50 * 1e6,
        s.p95 * 1e6,
        work / s.mean / 1e6
    );
}

fn main() {
    let mut rng = Xoshiro256pp::new(99);
    let l = 128usize;
    let d = 768usize;
    let dh = 64usize;

    // --- bit-level prediction unit ---------------------------------
    let x = MatI::from_fn(l, d, |_, _| rng.int_in(-128, 127) as i32);
    let wq = MatI::from_fn(d, dh, |_, _| rng.int_in(-128, 127) as i32);
    let s = bench(10, 3, || {
        std::hint::black_box(spls::predict_matmul(&x, &wq));
    });
    report("predict_matmul 128x768x64", (l * d * dh) as f64, s);

    let xs: Vec<i32> = (0..(1 << 16)).map(|_| rng.int_in(-128, 127) as i32).collect();
    let s = bench(20, 10, || {
        let mut acc = 0i64;
        for &v in &xs {
            acc += quant::hlog_quantize(v) as i64;
        }
        std::hint::black_box(acc);
    });
    report("hlog_quantize 64k", xs.len() as f64, s);

    // --- SPA pipeline ------------------------------------------------
    let pam = MatI::from_fn(l, l, |r, c| ((r / 2 * 31 + c * 7) % 97) as i32);
    let s = bench(20, 10, || {
        std::hint::black_box(spls::sparsify(&pam, 0.12));
    });
    report("topk sparsify 128x128", (l * l) as f64, s);

    let (spa, _) = spls::sparsify(&pam, 0.12);
    let s = bench(20, 10, || {
        std::hint::black_box(spls::local_similarity(&spa, 8, 0.6));
    });
    report("local_similarity w=8", (l * 7 * l) as f64, s);

    let spls_cfg = SplsConfig::default();
    let pams: Vec<MatI> = (0..4)
        .map(|h| MatI::from_fn(l, l, |r, c| ((r / 2 * 31 + c * 7 + h * 13) % 97) as i32))
        .collect();
    let s = bench(10, 5, || {
        std::hint::black_box(spls::plan_layer(&pams, &spls_cfg));
    });
    report("plan_layer 4 heads", (4 * l * l) as f64, s);

    // --- host tensor ops --------------------------------------------
    let a = MatF::from_fn(l, d, |_, _| rng.normal());
    let b = MatF::from_fn(d, d, |_, _| rng.normal());
    let s = bench(10, 3, || {
        std::hint::black_box(tensor::matmul(&a, &b));
    });
    report("host matmul 128x768x768", (l * d * d) as f64, s);

    let mut soft = MatF::from_fn(l, l, |_, _| rng.normal());
    let s = bench(20, 20, || {
        tensor::softmax_rows(&mut soft);
    });
    report("softmax_rows 128x128", (l * l) as f64, s);
}
