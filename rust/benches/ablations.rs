//! Ablation benches for the design choices DESIGN.md calls out:
//! quantization method, window size, PE-array shape, FACT-style
//! end-to-end comparison, and cluster-level batch scaling.

use esact::baselines::compare_with_fact;
use esact::config::{self, DeployConfig, HardwareConfig, SplsConfig};
use esact::model::{self, TestSet, TinyWeights};
use esact::quant::QuantMethod;
use esact::sim::{simulate_cluster, simulate_model, Features};
use esact::workloads::bench26::SparsityProfile;

fn main() -> anyhow::Result<()> {
    let hw = HardwareConfig::default();
    let spls = SplsConfig::default();
    let profile = SparsityProfile { q: 0.6, kv: 0.6, attn: 0.946, ffn: 0.5 };

    // --- quantization-method ablation (accuracy substrate) ----------
    println!("== quant method ablation (measured, 24 seqs) ==");
    let dir = esact::util::artifacts_dir();
    let w = TinyWeights::load(&dir.join("tiny_weights.bin"))?;
    let set = TestSet::load(&dir.join("tiny_testset.bin"))?;
    let dense = model::eval_dense(&w, &set, 24);
    for m in QuantMethod::ALL {
        let r = model::eval_sparse(&w, &set, 24, &spls, m);
        println!(
            "  {:<6} acc {:.4} (loss {:+.2}) | Q {:.3} KV {:.3}",
            m.name(),
            r.accuracy,
            r.loss_vs(&dense),
            r.q_sparsity,
            r.kv_sparsity
        );
    }

    // --- window-size ablation ----------------------------------------
    println!("\n== window size (measured Q sparsity at fixed s) ==");
    for window in [2usize, 4, 8, 16] {
        let cfg = SplsConfig { window, ..spls };
        let r = model::eval_sparse(&w, &set, 24, &cfg, QuantMethod::Hlog);
        let cmp = esact::workloads::flops::local_similarity_comparisons(64, window);
        println!(
            "  w={window:<3} Q sparsity {:.3} | acc {:.4} | comparisons {cmp}",
            r.q_sparsity, r.accuracy
        );
    }

    // --- PE shape ------------------------------------------------------
    println!("\n== PE-array shape (BERT-Base/128, full features) ==");
    let cfg = config::bert_base(128);
    for (rows, cols) in [(8usize, 128usize), (16, 64), (32, 32)] {
        let hw2 = HardwareConfig { pe_rows: rows, pe_cols: cols, ..hw };
        let r = simulate_model(&cfg, &hw2, &spls, &profile, Features::FULL);
        println!(
            "  {rows:>2}×{cols:<3} {:>9} cycles | util {:.3}",
            r.cycles,
            r.pe_utilization(&hw2)
        );
    }

    // --- FACT end-to-end comparison ------------------------------------
    println!("\n== ESACT vs FACT-style (no inter-row / no FFN sparsity) ==");
    for cfg in [config::bert_base(128), config::bert_large(512), config::gpt2(512)] {
        let c = compare_with_fact(&cfg, &hw, &spls, &profile);
        println!(
            "  {:>11} L={:<4} FACT {:>8.2} ms vs ESACT {:>8.2} ms → {:.2}×",
            cfg.name,
            cfg.seq_len,
            c.fact_seconds * 1e3,
            c.esact_seconds * 1e3,
            c.speedup
        );
    }

    // --- cluster scaling -------------------------------------------------
    println!("\n== 125-unit cluster scaling (BERT-Base/128) ==");
    let dep = DeployConfig::default();
    let cfg = config::bert_base(128);
    for batch in [1usize, 8, 25, 125, 500] {
        let (c, _) = simulate_cluster(&cfg, &hw, &spls, &profile, &dep, batch, Features::FULL);
        println!(
            "  batch {batch:>4}: {:>9.1} seq/s | cluster util {:.3}",
            c.throughput_seq_s, c.cluster_utilization
        );
    }
    Ok(())
}
