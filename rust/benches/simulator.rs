//! Cycle-simulator benchmarks: per-model simulated performance (the
//! Fig 20/21 inputs) plus simulator wall-clock throughput, and the
//! DRAM-model sanity row (paper: 4.7 GB/s max per unit).

use esact::config::{self, HardwareConfig, SplsConfig};
use esact::sim::{ablation, simulate_model, Features};
use esact::util::stats::bench;
use esact::workloads::all_benchmarks;
use esact::workloads::bench26::SparsityProfile;

fn main() {
    let hw = HardwareConfig::default();
    let spls = SplsConfig::default();
    let profile = SparsityProfile { q: 0.6, kv: 0.6, attn: 0.946, ffn: 0.5 };

    println!("== simulated per-model ablation (paper Fig 20 inputs) ==");
    for cfg in [
        config::bert_base(128),
        config::bert_base(384),
        config::bert_large(512),
        config::gpt2(512),
        config::llama2_7b(512),
        config::vit_b16(),
    ] {
        let [d, s, p, f] = ablation(&cfg, &hw, &spls, &profile);
        println!(
            "{:>11} L={:<4} SPLS ×{:.2} prog ×{:.2} dyn ×{:.2} | full {:>9.2} ms | BW {:.2} GB/s",
            cfg.name,
            cfg.seq_len,
            d.cycles as f64 / s.cycles as f64,
            s.cycles as f64 / p.cycles as f64,
            p.cycles as f64 / f.cycles as f64,
            f.seconds(&hw) * 1e3,
            f.peak_bw / 1e9,
        );
    }

    println!("\n== max per-unit bandwidth across the 26-benchmark zoo ==");
    let mut max_bw = 0.0f64;
    for b in all_benchmarks() {
        let r = simulate_model(&b.model, &hw, &spls, &b.profile, Features::FULL);
        max_bw = max_bw.max(r.peak_bw);
    }
    println!(
        "max {:.2} GB/s vs {:.2} GB/s per-unit share (paper: 4.7 vs 7.2) — compute-bound ✓",
        max_bw / 1e9,
        hw.dram_bw / 1e9
    );

    println!("\n== simulator wall-clock ==");
    let cfg = config::bert_large(512);
    let s = bench(10, 3, || {
        std::hint::black_box(simulate_model(&cfg, &hw, &spls, &profile, Features::FULL));
    });
    println!(
        "simulate_model BERT-Large/512: {:.2} ms/run (p95 {:.2})",
        s.mean * 1e3,
        s.p95 * 1e3
    );
}
