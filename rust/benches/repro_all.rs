//! The full experiment regeneration: every table AND figure from the
//! paper's evaluation, printed in paper shape with the paper's values
//! alongside. This is the bench target referenced by DESIGN.md's
//! experiment index (`make bench` runs it).

use std::time::Instant;

use esact::report::{figures, tables};

fn main() -> anyhow::Result<()> {
    let dir = &esact::util::artifacts_dir();
    let lim = 32; // accuracy-sweep size per point; full set via `esact eval`
    let t0 = Instant::now();
    let mut section = |name: &str, text: String| {
        println!("{text}\n{}\n", "=".repeat(72));
        eprintln!("[{:7.1}s] {name} done", t0.elapsed().as_secs_f64());
    };

    section("fig1", figures::fig1());
    section("fig3", figures::fig3(dir)?);
    section("fig4", figures::fig4(dir)?);
    section("fig6", figures::fig6(dir)?);
    section("fig7", figures::fig7());
    section("fig15", figures::fig15());
    section("fig16", figures::fig16(dir, lim)?);
    section("fig17", figures::fig17(dir, lim)?);
    section("fig18", figures::fig18(dir, lim)?);
    section("fig19", figures::fig19(dir, lim)?);
    section("fig20", figures::fig20());
    section("fig21", figures::fig21());
    section("table1", tables::table1());
    section("table2", tables::table2());
    section("table3", tables::table3());
    section("table4", tables::table4());

    eprintln!("repro_all complete in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
