//! End-to-end HTTP gateway benchmarks: closed-loop classify throughput
//! and latency over loopback TCP across a connections × replicas
//! surface, an open-loop Poisson cell, and generate-stream
//! time-to-first-token — the network-tier complement of the in-process
//! serving bench (`benches/serving.rs`), and the payload of CI's
//! schema-5 bench gate.
//!
//! Set `ESACT_BENCH_JSON=BENCH_5.json` to emit the machine-readable
//! report `scripts/bench_gate.py` compares against the committed
//! `bench_baseline.json`. The `ttft_frac` field is the structural
//! streaming check: time-to-first-token as a fraction of the whole
//! stream's wall time — near 1.0 would mean the gateway buffered the
//! stream instead of chunking it out as tokens were produced, however
//! fast the machine is.
//!
//! The event-loop rewrite adds two more groups: a `conn_sweep` holding
//! {64, 256, 1024} idle keep-alive connections while 4 active
//! connections run the closed loop (per-idle-connection memory must
//! stay flat and throughput must not invert as the herd grows), and a
//! `slow_loris` cell where half-open connections trickle bytes and the
//! gateway must reap every one of them on the idle timer while real
//! traffic keeps flowing.

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::{Duration, Instant};

use esact::config::SplsConfig;
use esact::coordinator::{Mode, Server};
use esact::net::client::{
    closed_loop_classify, generate_body, metric_value, open_lorises, poisson_classify,
    HttpClient, IdleConns,
};
use esact::net::poll::raise_nofile_limit;
use esact::net::{Gateway, GatewayConfig};
use esact::util::fault::{FaultPlan, FaultSite};
use esact::util::rng::Xoshiro256pp;

struct Cell {
    replicas: usize,
    connections: usize,
    requests: usize,
    throughput_rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    shed: usize,
}

impl Cell {
    fn print(&self) {
        println!(
            "  x{} replicas, {} conns: {:>7.1} rps | p50 {:>6.2} ms p99 {:>6.2} ms | {} shed",
            self.replicas,
            self.connections,
            self.throughput_rps,
            self.p50_ms,
            self.p99_ms,
            self.shed
        );
    }

    fn json(&self) -> String {
        format!(
            "{{\"replicas\": {}, \"connections\": {}, \"requests\": {}, \
             \"throughput_rps\": {:.2}, \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"shed\": {}}}",
            self.replicas,
            self.connections,
            self.requests,
            self.throughput_rps,
            self.p50_ms,
            self.p99_ms,
            self.shed
        )
    }
}

fn request_pool(l: usize, distinct: usize) -> Vec<Vec<i32>> {
    let mut rng = Xoshiro256pp::new(6);
    (0..distinct).map(|_| esact::model::synth::gen_example(&mut rng, l).0).collect()
}

fn start_gateway(replicas: usize, steps_per_slice: usize) -> anyhow::Result<(Gateway, String)> {
    start_gateway_with(replicas, steps_per_slice, Duration::from_secs(60))
}

fn start_gateway_with(
    replicas: usize,
    steps_per_slice: usize,
    idle_timeout: Duration,
) -> anyhow::Result<(Gateway, String)> {
    let dir = esact::util::artifacts_dir();
    let srv = Arc::new(Server::new(&dir, Mode::Dense, SplsConfig::default())?);
    start_with_server(srv, replicas, steps_per_slice, idle_timeout)
}

/// A gateway over a fault-armed server — the chaos cell's entry point.
fn start_gateway_faulted(
    replicas: usize,
    steps_per_slice: usize,
    plan: FaultPlan,
) -> anyhow::Result<(Gateway, String)> {
    let dir = esact::util::artifacts_dir();
    let srv =
        Arc::new(Server::with_fault_plan(&dir, Mode::Dense, SplsConfig::default(), plan)?);
    start_with_server(srv, replicas, steps_per_slice, Duration::from_secs(60))
}

fn start_with_server(
    srv: Arc<Server>,
    replicas: usize,
    steps_per_slice: usize,
    idle_timeout: Duration,
) -> anyhow::Result<(Gateway, String)> {
    // max_conns bounds concurrent *sockets* on the event loop — the
    // sweep below parks 1024 idle connections on one gateway
    let cfg = GatewayConfig::builder()
        .replicas(replicas)
        .max_conns(2048)
        .steps_per_slice(steps_per_slice)
        .idle_timeout(idle_timeout)
        .build()?;
    let gw = Gateway::start(srv, cfg)?;
    let addr = gw.local_addr().to_string();
    Ok((gw, addr))
}

/// A gateway with an explicit trace sampling knob — the tracing
/// overhead cell compares 1-in-1 sampling against tracing disabled.
fn start_gateway_traced(
    replicas: usize,
    steps_per_slice: usize,
    trace_sample: u64,
) -> anyhow::Result<(Gateway, String)> {
    let dir = esact::util::artifacts_dir();
    let srv = Arc::new(Server::new(&dir, Mode::Dense, SplsConfig::default())?);
    let cfg = GatewayConfig::builder()
        .replicas(replicas)
        .max_conns(2048)
        .steps_per_slice(steps_per_slice)
        .trace_sample(trace_sample)
        .build()?;
    let gw = Gateway::start(srv, cfg)?;
    let addr = gw.local_addr().to_string();
    Ok((gw, addr))
}

/// Resident set of this process (gateway + held client sockets live in
/// the same address space) in kB, from /proc/self/status.
fn rss_kb() -> anyhow::Result<f64> {
    let status = std::fs::read_to_string("/proc/self/status")?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            if let Some(kb) = rest.split_whitespace().next() {
                return Ok(kb.parse::<f64>()?);
            }
        }
    }
    anyhow::bail!("no VmRSS row in /proc/self/status")
}

struct SweepCell {
    idle_conns: usize,
    throughput_rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    rss_kb: f64,
}

fn main() -> anyhow::Result<()> {
    let dir = esact::util::artifacts_dir();
    let probe = Server::new(&dir, Mode::Dense, SplsConfig::default())?;
    let l = probe.seq_len();
    drop(probe);
    let pool = request_pool(l, 16);
    let n_per_cell = 48usize;

    // --- closed-loop surface: connections × replicas ----------------
    println!("== HTTP closed-loop classify (loopback, {n_per_cell} requests/cell) ==");
    let mut cells: Vec<Cell> = Vec::new();
    for replicas in [1usize, 2] {
        for connections in [1usize, 4, 8] {
            // fresh gateway per cell: every cell pays the same cold
            // start, mirroring the serving bench's methodology
            let (gw, addr) = start_gateway(replicas, 4)?;
            let report = closed_loop_classify(&addr, connections, n_per_cell, &pool)?;
            assert_eq!(
                report.ok + report.shed + report.errors,
                n_per_cell,
                "every request must be answered"
            );
            assert_eq!(report.errors, 0, "loopback closed loop must not error");
            let cell = Cell {
                replicas,
                connections,
                requests: n_per_cell,
                throughput_rps: report.throughput_rps(),
                p50_ms: report.p50_ms(),
                p99_ms: report.p99_ms(),
                shed: report.shed,
            };
            cell.print();
            cells.push(cell);
            gw.shutdown()?;
        }
    }

    // --- one open-loop Poisson cell (printed, lightly gated) --------
    println!("== HTTP open-loop Poisson (2 replicas, 4 conns) ==");
    let (gw, addr) = start_gateway(2, 4)?;
    // offer ~60% of the measured 2-replica closed-loop capacity
    let capacity = cells
        .iter()
        .find(|c| c.replicas == 2 && c.connections == 8)
        .map(|c| c.throughput_rps)
        .unwrap_or(50.0);
    let rate = (capacity * 0.6).max(5.0);
    let poisson = poisson_classify(&addr, rate, n_per_cell, 4, &pool, 9)?;
    println!(
        "  offered {:.0} rps: {:.1} rps served | p50 {:.2} ms p99 {:.2} ms | {} shed",
        rate,
        poisson.throughput_rps(),
        poisson.p50_ms(),
        poisson.p99_ms(),
        poisson.shed
    );
    gw.shutdown()?;

    // --- streaming: time-to-first-token -----------------------------
    println!("== HTTP generate streaming (2 replicas, 4 sessions) ==");
    let (gw, addr) = start_gateway(2, 2)?;
    let mut client = HttpClient::connect(&addr)?;
    let prompt: Vec<i32> = pool[0][..16].to_vec();
    let max_new = 16usize;
    let mut ttfts_ms: Vec<f64> = Vec::new();
    let mut fracs: Vec<f64> = Vec::new();
    let mut tokens = 0usize;
    let mut stream_secs = 0f64;
    for _ in 0..4 {
        let stream = client.generate_stream(&generate_body(&prompt, max_new, None))?;
        let result = stream.collect()?;
        let ttft = result.ttft.expect("stream produced tokens").as_secs_f64();
        let wall = result.wall.as_secs_f64().max(1e-9);
        ttfts_ms.push(ttft * 1e3);
        fracs.push(ttft / wall);
        tokens += result.tokens.len();
        stream_secs += wall;
    }
    gw.shutdown()?;
    ttfts_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ttft_ms = ttfts_ms[ttfts_ms.len() / 2];
    let ttft_frac = fracs.iter().sum::<f64>() / fracs.len() as f64;
    let stream_tps = tokens as f64 / stream_secs.max(1e-9);
    println!(
        "  {tokens} tokens over 4 sessions: {stream_tps:.1} tok/s | \
         ttft {ttft_ms:.1} ms (frac {ttft_frac:.2})"
    );

    // --- C10K conn sweep: idle herd + 4 active connections ----------
    // one gateway holds a growing herd of idle keep-alive connections
    // while 4 active connections run the closed loop: throughput must
    // not invert as the herd grows, the marginal memory per idle
    // connection must stay flat, and the oldest held sockets must
    // still answer requests at the top of the sweep
    let _ = raise_nofile_limit(4096);
    println!("== HTTP conn sweep (1 replica, 4 active conns, growing idle herd) ==");
    let sweep_sizes = [64usize, 256, 1024];
    let (gw, addr) = start_gateway(1, 4)?;
    let mut herds: Vec<IdleConns> = Vec::new();
    let mut held = 0usize;
    let mut sweep: Vec<SweepCell> = Vec::new();
    for &target in &sweep_sizes {
        herds.push(IdleConns::open(&addr, target - held)?);
        held = target;
        // let the event loop accept and register the whole herd
        std::thread::sleep(Duration::from_millis(100));
        let rss = rss_kb()?;
        let report = closed_loop_classify(&addr, 4, n_per_cell, &pool)?;
        assert_eq!(report.errors, 0, "closed loop must not error under the idle herd");
        let cell = SweepCell {
            idle_conns: target,
            throughput_rps: report.throughput_rps(),
            p50_ms: report.p50_ms(),
            p99_ms: report.p99_ms(),
            rss_kb: rss,
        };
        println!(
            "  {:>5} idle conns: {:>7.1} rps | p50 {:>6.2} ms p99 {:>6.2} ms | rss {:.0} kB",
            cell.idle_conns, cell.throughput_rps, cell.p50_ms, cell.p99_ms, cell.rss_kb
        );
        sweep.push(cell);
    }
    // marginal memory per idle connection across the sweep's span (the
    // allocator may hand back reused pages, so clamp at zero)
    let span = (sweep_sizes[sweep_sizes.len() - 1] - sweep_sizes[0]) as f64;
    let idle_kb_per_conn =
        ((sweep[sweep.len() - 1].rss_kb - sweep[0].rss_kb) / span).max(0.0);
    // the oldest herd was parked through the whole sweep — every one of
    // its sockets must still complete a request
    let oldest = herds[0].len();
    let alive = herds[0].probe_all()?;
    assert_eq!(alive, oldest, "only {alive}/{oldest} of the oldest idle conns still serve");
    println!(
        "  idle memory: {idle_kb_per_conn:.1} kB/conn marginal | oldest {oldest} conns all alive"
    );
    drop(herds);
    gw.shutdown()?;

    // --- slow loris: half-open conns must be reaped, traffic flows --
    println!("== HTTP slow-loris (1 replica, 300 ms idle timeout, 32 lorises) ==");
    let n_lorises = 32usize;
    let (gw, addr) = start_gateway_with(1, 4, Duration::from_millis(300))?;
    let lorises = open_lorises(&addr, n_lorises)?;
    // real traffic keeps flowing while the lorises squat
    let loris_report = closed_loop_classify(&addr, 4, n_per_cell, &pool)?;
    assert_eq!(loris_report.errors, 0, "closed loop must not error under loris pressure");
    // the idle timer must reap every loris (they never complete a
    // request, so idle expiry counts from the connection's start)
    let mut probe = HttpClient::connect(&addr)?;
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut reaped = 0usize;
    while Instant::now() < deadline {
        reaped = metric_value(&mut probe, "esact_gateway_conns_reaped_total")?
            .unwrap_or(0.0) as usize;
        if reaped >= n_lorises {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    println!(
        "  {reaped}/{n_lorises} lorises reaped | {:.1} rps under loris pressure",
        loris_report.throughput_rps()
    );
    drop(lorises);
    gw.shutdown()?;

    // --- chaos: goodput under ~1% injected replica panics -----------
    // classify jobs panic at a seeded ~1% rate plus a guaranteed
    // every-20th trip: the 4-conn closed loop caps batches at 4
    // requests, so the 96 requests produce at least 24 job executions
    // and the deterministic trip always exercises the supervisor;
    // retried batches must keep goodput within 20% of the fault-free
    // 2-replica cell — the gate's BENCH_5 fault floor
    println!("== HTTP classify under ~1% injected replica faults (2 replicas, 4 conns) ==");
    let fault_free_rps = cells
        .iter()
        .find(|c| c.replicas == 2 && c.connections == 4)
        .map(|c| c.throughput_rps)
        .unwrap_or(capacity);
    let fault_rate = 0.01f64;
    let fault_requests = n_per_cell * 2;
    let plan = FaultPlan::seeded(17)
        .with_rate(FaultSite::ClassifyJob, fault_rate)
        .with_every(FaultSite::ClassifyJob, 20);
    let (gw, addr) = start_gateway_faulted(2, 4, plan)?;
    let chaos = closed_loop_classify(&addr, 4, fault_requests, &pool)?;
    let mut probe = HttpClient::connect(&addr)?;
    let respawns =
        metric_value(&mut probe, "esact_replica_respawns_total")?.unwrap_or(0.0) as usize;
    let retried = metric_value(&mut probe, "esact_jobs_retried_total")?.unwrap_or(0.0) as usize;
    drop(probe);
    gw.shutdown()?;
    assert_eq!(
        chaos.ok + chaos.shed + chaos.errors,
        fault_requests,
        "every request must be answered under injected faults"
    );
    let goodput_rps = chaos.throughput_rps();
    let goodput_frac = if fault_free_rps > 0.0 { goodput_rps / fault_free_rps } else { 1.0 };
    println!(
        "  {goodput_rps:.1} rps goodput ({:.0}% of fault-free {fault_free_rps:.1} rps) | \
         {respawns} respawns {retried} retried | {} ok {} errors",
        goodput_frac * 100.0,
        chaos.ok,
        chaos.errors
    );

    // --- tracing overhead: 1-in-1 spans + histograms vs disabled ----
    // same closed-loop cell twice: once with every request traced
    // (span ring writes + histogram observes on the hot path), once
    // with the sampler off. The gate's BENCH_5 tracing cell fails if
    // full tracing costs more than 10% of throughput. The traced run
    // also scrapes its own /metrics and reports the queue-wait and
    // execute stage medians recovered from the exported histograms.
    println!("== HTTP classify tracing overhead (2 replicas, 4 conns) ==");
    let trace_requests = n_per_cell * 2;
    let (gw, addr) = start_gateway_traced(2, 4, 1)?;
    let mut traced = closed_loop_classify(&addr, 4, trace_requests, &pool)?;
    assert_eq!(traced.errors, 0, "traced closed loop must not error");
    let mut probe = HttpClient::connect(&addr)?;
    traced.scrape_stages(&mut probe)?;
    drop(probe);
    gw.shutdown()?;
    let (gw, addr) = start_gateway_traced(2, 4, 0)?;
    let untraced = closed_loop_classify(&addr, 4, trace_requests, &pool)?;
    assert_eq!(untraced.errors, 0, "untraced closed loop must not error");
    gw.shutdown()?;
    let rps_on = traced.throughput_rps();
    let rps_off = untraced.throughput_rps();
    let overhead_frac = if rps_off > 0.0 { (rps_off - rps_on) / rps_off } else { 0.0 };
    let queue_wait_p50_ms = traced.queue_wait_p50_ms.unwrap_or(0.0);
    let execute_p50_ms = traced.execute_p50_ms.unwrap_or(0.0);
    println!(
        "  traced {rps_on:.1} rps vs untraced {rps_off:.1} rps ({:+.1}% overhead) | \
         stage medians: queue-wait {queue_wait_p50_ms:.2} ms execute {execute_p50_ms:.2} ms",
        overhead_frac * 100.0
    );

    // --- machine-readable report for the CI gate --------------------
    if let Ok(path) = std::env::var("ESACT_BENCH_JSON") {
        let mut out = String::from("{\n  \"schema\": 5,\n");
        let join =
            |cells: &[Cell]| cells.iter().map(Cell::json).collect::<Vec<_>>().join(",\n    ");
        let _ = writeln!(out, "  \"gateway\": [\n    {}\n  ],", join(&cells));
        let _ = writeln!(
            out,
            "  \"poisson\": {{\"offered_rps\": {:.1}, \"throughput_rps\": {:.2}, \
             \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"shed\": {}}},",
            rate,
            poisson.throughput_rps(),
            poisson.p50_ms(),
            poisson.p99_ms(),
            poisson.shed
        );
        let _ = writeln!(
            out,
            "  \"streaming\": {{\"sessions\": 4, \"tokens\": {tokens}, \
             \"ttft_ms\": {ttft_ms:.3}, \"ttft_frac\": {ttft_frac:.3}, \
             \"tokens_per_sec\": {stream_tps:.2}}},"
        );
        let sweep_json = sweep
            .iter()
            .map(|c| {
                format!(
                    "{{\"idle_conns\": {}, \"throughput_rps\": {:.2}, \
                     \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"rss_kb\": {:.0}}}",
                    c.idle_conns, c.throughput_rps, c.p50_ms, c.p99_ms, c.rss_kb
                )
            })
            .collect::<Vec<_>>()
            .join(",\n      ");
        let _ = writeln!(
            out,
            "  \"conn_sweep\": {{\"active_conns\": 4, \
             \"idle_kb_per_conn\": {idle_kb_per_conn:.2}, \"cells\": [\n      \
             {sweep_json}\n  ]}},"
        );
        let _ = writeln!(
            out,
            "  \"slow_loris\": {{\"lorises\": {n_lorises}, \"reaped\": {reaped}, \
             \"throughput_rps\": {:.2}}},",
            loris_report.throughput_rps()
        );
        let _ = writeln!(
            out,
            "  \"fault\": {{\"rate\": {fault_rate}, \"requests\": {fault_requests}, \
             \"ok\": {}, \"errors\": {}, \"respawns\": {respawns}, \"retried\": {retried}, \
             \"throughput_rps\": {goodput_rps:.2}, \"fault_free_rps\": {fault_free_rps:.2}, \
             \"goodput_frac\": {goodput_frac:.3}}},",
            chaos.ok, chaos.errors
        );
        let _ = writeln!(
            out,
            "  \"tracing\": {{\"requests\": {trace_requests}, \"rps_on\": {rps_on:.2}, \
             \"rps_off\": {rps_off:.2}, \"overhead_frac\": {overhead_frac:.3}, \
             \"queue_wait_p50_ms\": {queue_wait_p50_ms:.3}, \
             \"execute_p50_ms\": {execute_p50_ms:.3}}}"
        );
        out.push_str("}\n");
        std::fs::write(&path, out)?;
        println!("\nwrote {path}");
    }
    Ok(())
}
