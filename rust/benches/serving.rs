//! Serving-tier benchmarks: executor latency (batch 1 vs 8), SPLS
//! planning cost cold vs plan-cache hit, and the coordinator's
//! **latency-vs-load-vs-replicas surface** — saturated throughput
//! scaling from 1 → 4 replicas under Poisson load, plus open-loop
//! latency percentiles across offered-load levels. These are the
//! end-to-end serving measurements (DESIGN.md §Serving coordinator) and
//! the payload of CI's bench-regression gate.
//!
//! Set `ESACT_BENCH_JSON=BENCH_2.json` to emit the machine-readable
//! report (p50/p99 latency, throughput per replica, plan-cache hit
//! rate) that `scripts/bench_gate.py` compares against the committed
//! `bench_baseline.json`.

use std::fmt::Write as _;
use std::sync::mpsc;
use std::time::Instant;

use esact::config::SplsConfig;
use esact::coordinator::server::Mode;
use esact::coordinator::{arrivals, Arrival, BatchPolicy, Request, Server};
use esact::model::{self, TinyWeights};
use esact::quant::QuantMethod;
use esact::runtime::{Arg, ArtifactSet};
use esact::spls::SharedPlanCache;
use esact::util::rng::Xoshiro256pp;
use esact::util::stats::bench;

/// One measured cell of the serving surface.
struct Cell {
    mode: Mode,
    replicas: usize,
    /// Offered Poisson rate; 0.0 marks a pre-loaded (saturated) run.
    offered_rps: f64,
    throughput_rps: f64,
    per_replica_rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    cache_hit_rate: f64,
    steals: usize,
}

impl Cell {
    fn of(mode: Mode, offered_rps: f64, m: &esact::coordinator::ServeMetrics) -> Cell {
        Cell {
            mode,
            replicas: m.replicas,
            offered_rps,
            throughput_rps: m.throughput_rps(),
            per_replica_rps: m.throughput_per_replica(),
            p50_ms: m.p50_latency.as_secs_f64() * 1e3,
            p99_ms: m.p99_latency.as_secs_f64() * 1e3,
            cache_hit_rate: m.plan_cache.hit_rate(),
            steals: m.steals,
        }
    }

    fn print(&self) {
        let mode = if self.mode == Mode::Dense { "dense" } else { "spls" };
        let offered = if self.offered_rps > 0.0 {
            format!("{:.0}", self.offered_rps)
        } else {
            "sat".to_string()
        };
        println!(
            "  {:<5} x{} @ {:>7} rps offered: {:>7.1} rps ({:>6.1}/replica) | \
             p50 {:>7.2} ms p99 {:>7.2} ms | cache {:>3.0}% | {} steals",
            mode,
            self.replicas,
            offered,
            self.throughput_rps,
            self.per_replica_rps,
            self.p50_ms,
            self.p99_ms,
            self.cache_hit_rate * 100.0,
            self.steals
        )
    }

    fn json(&self) -> String {
        format!(
            "{{\"mode\": \"{:?}\", \"replicas\": {}, \"offered_rps\": {:.1}, \
             \"throughput_rps\": {:.2}, \"throughput_per_replica\": {:.2}, \
             \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"plan_cache_hit_rate\": {:.3}, \
             \"steals\": {}}}",
            self.mode,
            self.replicas,
            self.offered_rps,
            self.throughput_rps,
            self.per_replica_rps,
            self.p50_ms,
            self.p99_ms,
            self.cache_hit_rate,
            self.steals
        )
    }
}

/// Pool of distinct request sequences; serving replays it round-robin
/// so the plan cache sees a realistic repeated-shape mix.
fn request_pool(l: usize, distinct: usize) -> Vec<Vec<i32>> {
    let mut rng = Xoshiro256pp::new(3);
    (0..distinct).map(|_| model::synth::gen_example(&mut rng, l).0).collect()
}

fn requests(pool: &[Vec<i32>], n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i as u64,
            tokens: pool[i % pool.len()].clone(),
            arrived: Instant::now(),
        })
        .collect()
}

/// Saturated (pre-loaded queue) run: measures peak service capacity.
fn closed_loop(srv: &Server, mode: Mode, pool: &[Vec<i32>], n: usize, replicas: usize) -> Cell {
    let (tx, rx) = mpsc::channel();
    let (rtx, rrx) = mpsc::channel();
    for r in requests(pool, n) {
        tx.send(r).unwrap();
    }
    drop(tx);
    let drain = std::thread::spawn(move || rrx.iter().count());
    let outcome = srv.serve_replicated(rx, rtx, BatchPolicy::default(), replicas).unwrap();
    assert_eq!(drain.join().unwrap(), n);
    Cell::of(mode, 0.0, &outcome.metrics)
}

/// Open-loop Poisson run at `rate` requests/second.
fn open_loop(srv: &Server, pool: &[Vec<i32>], n: usize, rate: f64, replicas: usize) -> Cell {
    let (tx, rx) = mpsc::channel();
    let (rtx, rrx) = mpsc::channel();
    let reqs = requests(pool, n);
    let producer = std::thread::spawn(move || {
        let mut rng = Xoshiro256pp::new(7);
        let trace = arrivals(&mut rng, Arrival::Poisson { rate }, reqs.len());
        let start = Instant::now();
        for (mut r, at) in reqs.into_iter().zip(trace) {
            if let Some(wait) = at.0.checked_sub(start.elapsed()) {
                std::thread::sleep(wait);
            }
            r.arrived = Instant::now();
            if tx.send(r).is_err() {
                break;
            }
        }
    });
    let drain = std::thread::spawn(move || rrx.iter().count());
    let outcome = srv.serve_replicated(rx, rtx, BatchPolicy::default(), replicas).unwrap();
    producer.join().unwrap();
    drain.join().unwrap();
    Cell::of(Mode::Spls, rate, &outcome.metrics)
}

fn main() -> anyhow::Result<()> {
    let dir = esact::util::artifacts_dir();
    let artifacts = ArtifactSet::load(&dir)?;
    let weights = TinyWeights::load(&dir.join("tiny_weights.bin"))?;
    let mut rng = Xoshiro256pp::new(2);
    let l = weights.cfg.seq_len;
    let pool = request_pool(l, 16);

    // --- raw executable latency -------------------------------------
    let toks1: Vec<i32> = (0..l).map(|_| rng.below(64) as i32).collect();
    let s1 = bench(20, 5, || {
        artifacts.dense_b1.run_f32(&[Arg::I32(&toks1, &[1, l])]).unwrap();
    });
    println!(
        "dense_b1 execute             {:>8.2} ms/seq (p95 {:.2})",
        s1.mean * 1e3,
        s1.p95 * 1e3
    );

    let toks8: Vec<i32> = (0..8 * l).map(|_| rng.below(64) as i32).collect();
    let s8 = bench(20, 5, || {
        artifacts.dense_b8.run_f32(&[Arg::I32(&toks8, &[8, l])]).unwrap();
    });
    println!(
        "dense_b8 execute             {:>8.2} ms/batch = {:.2} ms/seq",
        s8.mean * 1e3,
        s8.mean * 1e3 / 8.0
    );

    // --- SPLS planning: cold vs plan-cache hit -----------------------
    let (tok_seq, _) = model::synth::gen_example(&mut rng, l);
    let spls = SplsConfig::default();
    let cold = bench(10, 3, || {
        std::hint::black_box(model::plan_model(&weights, &tok_seq, &spls, QuantMethod::Hlog));
    });
    println!("SPLS plan_model (cold)       {:>8.2} ms/seq", cold.mean * 1e3);
    let cache = SharedPlanCache::new(64);
    let n_layers = weights.cfg.n_layers;
    // populate once, then measure the hit path
    cache.get_or_compute(&tok_seq, &spls, QuantMethod::Hlog, n_layers, || {
        model::plan_model(&weights, &tok_seq, &spls, QuantMethod::Hlog)
    });
    let hit = bench(10, 3, || {
        std::hint::black_box(cache.get_or_compute(
            &tok_seq,
            &spls,
            QuantMethod::Hlog,
            n_layers,
            || unreachable!("warm cache"),
        ));
    });
    println!(
        "SPLS plan cache hit          {:>8.2} ms/seq ({:.0}x faster)",
        hit.mean * 1e3,
        cold.mean / hit.mean.max(1e-9)
    );

    // --- saturated throughput: 1 → 2 → 4 replicas --------------------
    println!("\n== saturated throughput vs replicas (closed loop, 64 requests) ==");
    let mut saturated: Vec<Cell> = Vec::new();
    for mode in [Mode::Dense, Mode::Spls] {
        for replicas in [1usize, 2, 4] {
            // fresh server per cell: every cell pays the same cold
            // plan-cache start
            let srv = Server::new(&dir, mode, SplsConfig::default())?;
            let cell = closed_loop(&srv, mode, &pool, 64, replicas);
            cell.print();
            saturated.push(cell);
        }
    }
    let spls_sat: Vec<&Cell> =
        saturated.iter().filter(|c| c.mode == Mode::Spls).collect();
    let monotone = spls_sat.windows(2).all(|w| w[1].throughput_rps >= w[0].throughput_rps);
    println!(
        "SPLS saturated scaling 1→2→4 replicas: {:.0} → {:.0} → {:.0} rps ({})",
        spls_sat[0].throughput_rps,
        spls_sat[1].throughput_rps,
        spls_sat[2].throughput_rps,
        if monotone { "monotone ✓" } else { "NOT monotone ✗" }
    );

    // --- the surface: Poisson offered load × replicas ----------------
    // calibrate offered rates off the measured single-replica capacity
    let t1 = spls_sat[0].throughput_rps.max(1.0);
    println!("\n== latency vs offered load vs replicas (Poisson, SPLS) ==");
    let mut poisson: Vec<Cell> = Vec::new();
    for replicas in [1usize, 2, 4] {
        for load_x in [0.5, 1.5, 8.0] {
            let rate = t1 * load_x;
            // bound each cell's wall time to ≈ 2.5 s of offered trace
            let n = ((rate * 2.5) as usize).clamp(16, 64);
            let srv = Server::new(&dir, Mode::Spls, SplsConfig::default())?;
            let cell = open_loop(&srv, &pool, n, rate, replicas);
            cell.print();
            poisson.push(cell);
        }
    }
    let sat_poisson: Vec<&Cell> =
        poisson.iter().filter(|c| (c.offered_rps - t1 * 8.0).abs() < 1e-6).collect();
    let monotone_poisson =
        sat_poisson.windows(2).all(|w| w[1].throughput_rps >= w[0].throughput_rps);
    println!(
        "SPLS Poisson-saturated scaling 1→2→4 replicas: {:.0} → {:.0} → {:.0} rps ({})",
        sat_poisson[0].throughput_rps,
        sat_poisson[1].throughput_rps,
        sat_poisson[2].throughput_rps,
        if monotone_poisson { "monotone ✓" } else { "NOT monotone ✗" }
    );

    // --- machine-readable report for the CI regression gate ----------
    if let Ok(path) = std::env::var("ESACT_BENCH_JSON") {
        let mut out = String::from("{\n  \"schema\": 2,\n");
        let _ = writeln!(
            out,
            "  \"executor\": {{\"dense_b1_p50_ms\": {:.3}, \"dense_b8_p50_ms\": {:.3}, \
             \"plan_model_cold_ms\": {:.3}, \"plan_cache_hit_ms\": {:.4}}},",
            s1.p50 * 1e3,
            s8.p50 * 1e3,
            cold.p50 * 1e3,
            hit.p50 * 1e3
        );
        let join = |cells: &[Cell]| {
            cells.iter().map(Cell::json).collect::<Vec<_>>().join(",\n    ")
        };
        let _ = writeln!(out, "  \"saturated\": [\n    {}\n  ],", join(&saturated));
        let _ = writeln!(out, "  \"poisson\": [\n    {}\n  ]", join(&poisson));
        out.push_str("}\n");
        std::fs::write(&path, out)?;
        println!("\nwrote {path}");
    }
    Ok(())
}
