//! Serving-path benchmarks: PJRT executable latency (batch 1 vs 8),
//! SPLS mask-planning cost, and coordinator throughput dense vs SPLS —
//! the end-to-end numbers recorded in EXPERIMENTS.md §E2E/§Perf.

use std::sync::mpsc;
use std::time::Instant;

use esact::config::SplsConfig;
use esact::coordinator::server::Mode;
use esact::coordinator::{BatchPolicy, Request, Server};
use esact::model::{self, TinyWeights};
use esact::quant::QuantMethod;
use esact::runtime::{Arg, ArtifactSet};
use esact::util::rng::Xoshiro256pp;
use esact::util::stats::bench;

fn main() -> anyhow::Result<()> {
    let dir = esact::util::artifacts_dir();
    let artifacts = ArtifactSet::load(&dir)?;
    let weights = TinyWeights::load(&dir.join("tiny_weights.bin"))?;
    let mut rng = Xoshiro256pp::new(2);
    let l = weights.cfg.seq_len;

    // --- raw executable latency -------------------------------------
    let toks1: Vec<i32> = (0..l).map(|_| rng.below(64) as i32).collect();
    let s = bench(20, 5, || {
        artifacts
            .dense_b1
            .run_f32(&[Arg::I32(&toks1, &[1, l])])
            .unwrap();
    });
    println!("dense_b1 PJRT execute        {:>8.2} ms/seq (p95 {:.2})", s.mean * 1e3, s.p95 * 1e3);

    let toks8: Vec<i32> = (0..8 * l).map(|_| rng.below(64) as i32).collect();
    let s = bench(20, 5, || {
        artifacts
            .dense_b8
            .run_f32(&[Arg::I32(&toks8, &[8, l])])
            .unwrap();
    });
    println!(
        "dense_b8 PJRT execute        {:>8.2} ms/batch = {:.2} ms/seq",
        s.mean * 1e3,
        s.mean * 1e3 / 8.0
    );

    // --- SPLS planning cost (host, per request) ----------------------
    let (tok_seq, _) = model::synth::gen_example(&mut rng, l);
    let spls = SplsConfig::default();
    let s = bench(10, 3, || {
        std::hint::black_box(model::plan_model(&weights, &tok_seq, &spls, QuantMethod::Hlog));
    });
    println!("SPLS plan_model (2 layers)   {:>8.2} ms/seq", s.mean * 1e3);

    // --- coordinator throughput --------------------------------------
    for mode in [Mode::Dense, Mode::Spls] {
        let srv = Server::new(&dir, mode, SplsConfig::default())?;
        let n = 64usize;
        let (tx, rx) = mpsc::channel();
        let (rtx, rrx) = mpsc::channel();
        let mut g = Xoshiro256pp::new(3);
        for i in 0..n {
            let (t, _) = model::synth::gen_example(&mut g, l);
            tx.send(Request { id: i as u64, tokens: t, arrived: Instant::now() })?;
        }
        drop(tx);
        let drain = std::thread::spawn(move || rrx.iter().count());
        let m = srv.serve(rx, rtx, BatchPolicy::default())?;
        drain.join().unwrap();
        println!(
            "serve {mode:?}: {:.0} req/s | mean latency {:.2} ms | {} batches",
            m.throughput_rps(),
            m.mean_latency().as_secs_f64() * 1e3,
            m.batches
        );
    }
    Ok(())
}
