//! Paged-KV serving benchmarks: aggregate decode throughput vs session
//! count at **fixed pool memory**, prefix-sharing hit rate, copy-on-
//! write divergence cost, and the blocks-allocated saving of sharing a
//! prompt prefix vs replaying it per session. Emits the machine-
//! readable `BENCH_6.json` report (set `ESACT_BENCH_JSON`) that
//! `scripts/bench_gate.py` gates against the committed
//! `bench_baseline.json`: per-session-count aggregate tokens/sec
//! floors, the headline aggregate-throughput-rises-with-sessions
//! check, a prefix-hit-rate floor, and the structural
//! sharing-allocates-fewer-blocks-than-no-sharing check.

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

use esact::config::SplsConfig;
use esact::decode::{
    DecodeConfig, DecodeEngine, DecodeMode, GenSession, PagedPool, PoolStats, Sampling,
};
use esact::model::TinyWeights;
use esact::util::rng::Xoshiro256pp;

/// K/V rows per pool block (the granularity of sharing).
const BLOCK_SIZE: usize = 8;
/// Hard pool cap — every cell runs inside the same fixed memory.
const POOL_BLOCKS: usize = 1024;
/// Shared prompt prefix length (6 full blocks per head chain).
const PREFIX_LEN: usize = 48;
/// Per-session distinct prompt tail.
const TAIL_LEN: usize = 4;
/// Greedy tokens generated per session.
const NEW_TOKENS: usize = 16;
/// Round-robin slice width (continuous-batch flavor).
const SLICE: usize = 4;
const REPS: usize = 3;

fn cfg() -> DecodeConfig {
    DecodeConfig {
        mode: DecodeMode::Spls,
        kv_budget: usize::MAX,
        recent: 4,
        spls: SplsConfig::default(),
    }
}

fn tokens(seed: u64, n: usize) -> Vec<i32> {
    let mut rng = Xoshiro256pp::new(seed);
    (0..n).map(|_| rng.below(64) as i32).collect()
}

struct Wave {
    wall: f64,
    stats: PoolStats,
}

/// Admit session 0 first and run it through its declared prefix (so it
/// publishes into the trie), then admit the rest (which attach when
/// their prefix matches) and drain everyone in round-robin slices —
/// the same leader shape `serve_generate` uses. The wall clock covers
/// admission + prefill + decode, so prefix sharing shows up as
/// aggregate throughput, not a hidden discount.
fn run_wave(
    engine: &Arc<DecodeEngine>,
    pool: &PagedPool,
    prefixes: &[Vec<i32>],
    tails: &[Vec<i32>],
    max_new: usize,
) -> Wave {
    let t0 = Instant::now();
    let mut sessions: Vec<GenSession> = Vec::with_capacity(prefixes.len());
    let mut first = GenSession::new_paged(
        Arc::clone(engine),
        cfg(),
        pool,
        &prefixes[0],
        tails[0].clone(),
        max_new,
        Sampling::Greedy,
    );
    first.run_steps(prefixes[0].len());
    sessions.push(first);
    for i in 1..prefixes.len() {
        sessions.push(GenSession::new_paged(
            Arc::clone(engine),
            cfg(),
            pool,
            &prefixes[i],
            tails[i].clone(),
            max_new,
            Sampling::Greedy,
        ));
    }
    loop {
        let mut live = false;
        for s in sessions.iter_mut() {
            if !s.done() {
                live = true;
                s.run_steps(SLICE);
            }
        }
        if !live {
            break;
        }
    }
    for s in &sessions {
        assert_eq!(s.generated().len(), max_new, "a session failed to drain");
    }
    // read the high-water mark before the sessions drop their blocks
    let stats = pool.stats();
    Wave { wall: t0.elapsed().as_secs_f64().max(1e-12), stats }
}

struct Cell {
    sessions: usize,
    tokens_per_sec: f64,
    blocks_peak: usize,
    hit_rate: f64,
}

impl Cell {
    fn print(&self) {
        println!(
            "  {:>3} sessions: {:>9.0} tok/s aggregate | peak {:>4} blocks | hit rate {:.3}",
            self.sessions, self.tokens_per_sec, self.blocks_peak, self.hit_rate
        );
    }

    fn json(&self) -> String {
        format!(
            "{{\"sessions\": {}, \"tokens_per_sec\": {:.2}, \"blocks_peak\": {}, \
             \"prefix_hit_rate\": {:.4}}}",
            self.sessions, self.tokens_per_sec, self.blocks_peak, self.hit_rate
        )
    }
}

/// Best-of-REPS aggregate throughput for `n` sessions sharing (or not
/// sharing) a prefix, each rep on a fresh pool so the block stats are
/// per-run. Pool stats are deterministic across reps.
fn run_cell(engine: &Arc<DecodeEngine>, dh: usize, prefixes: &[Vec<i32>]) -> Cell {
    let n = prefixes.len();
    let tails: Vec<Vec<i32>> = (0..n).map(|i| tokens(100 + i as u64, TAIL_LEN)).collect();
    let mut best = 0.0f64;
    let mut stats: Option<PoolStats> = None;
    for _ in 0..REPS {
        let pool = PagedPool::new(BLOCK_SIZE, POOL_BLOCKS, dh);
        let w = run_wave(engine, &pool, prefixes, &tails, NEW_TOKENS);
        best = best.max((n * NEW_TOKENS) as f64 / w.wall);
        stats = Some(w.stats);
    }
    let st = stats.unwrap();
    Cell {
        sessions: n,
        tokens_per_sec: best,
        blocks_peak: st.peak,
        hit_rate: st.hit_rate(),
    }
}

fn main() -> anyhow::Result<()> {
    let dir = esact::util::artifacts_dir();
    let weights = Arc::new(TinyWeights::load(&dir.join("tiny_weights.bin"))?);
    let dh = weights.cfg.d_head();
    let engine = Arc::new(DecodeEngine::new(weights));
    let prefix = tokens(11, PREFIX_LEN);

    // --- aggregate throughput vs session count, fixed pool memory ----
    println!(
        "== paged decode: aggregate tok/s vs sessions (pool {POOL_BLOCKS} x {BLOCK_SIZE} rows, \
         prefix {PREFIX_LEN}, {NEW_TOKENS} new tokens each) =="
    );
    let mut cells: Vec<Cell> = Vec::new();
    for n in [1usize, 8, 32] {
        let prefixes: Vec<Vec<i32>> = (0..n).map(|_| prefix.clone()).collect();
        let cell = run_cell(&engine, dh, &prefixes);
        cell.print();
        cells.push(cell);
    }
    // the S=32 cell is the hit-rate headline: 1 publisher miss, 31 attaches
    let hit_rate = cells.last().map(|c| c.hit_rate).unwrap_or(0.0);
    println!("  prefix-sharing hit rate @ 32 sessions: {hit_rate:.3}");

    // --- copy-on-write divergence: shared *partial* tail block --------
    // A 50-token prefix leaves a 2-row partial block in the trie entry;
    // every session's first push past it must copy that block, not
    // write through the shared rows.
    println!("\n== copy-on-write divergence (prefix 50 = 6 blocks + 2-row partial, 8 sessions) ==");
    let cow_prefix = tokens(7, 50);
    let cow_sessions = 8usize;
    let pool = PagedPool::new(BLOCK_SIZE, POOL_BLOCKS, dh);
    let cow_prefixes: Vec<Vec<i32>> = (0..cow_sessions).map(|_| cow_prefix.clone()).collect();
    let cow_tails: Vec<Vec<i32>> = (0..cow_sessions).map(|i| tokens(200 + i as u64, 2)).collect();
    let cow_wave = run_wave(&engine, &pool, &cow_prefixes, &cow_tails, 4);
    println!(
        "  {} CoW block copies, {} prefix tokens served shared, peak {} blocks",
        cow_wave.stats.cow_copies, cow_wave.stats.shared_attach_tokens, cow_wave.stats.peak
    );

    // --- sharing vs no-sharing: blocks allocated at 8 sessions -------
    println!("\n== prefix sharing vs private replay (8 sessions, peak blocks) ==");
    let share_sessions = 8usize;
    let shared: Vec<Vec<i32>> = (0..share_sessions).map(|_| prefix.clone()).collect();
    let mut private: Vec<Vec<i32>> = Vec::with_capacity(share_sessions);
    for i in 0..share_sessions {
        // same length, pairwise-distinct first token: every session
        // declares a prefix nobody else published, so nothing attaches
        let mut p = prefix.clone();
        p[0] = i as i32;
        private.push(p);
    }
    let sharing = run_cell(&engine, dh, &shared);
    let nosharing = run_cell(&engine, dh, &private);
    println!(
        "  sharing peak {:>4} blocks vs no-sharing peak {:>4} blocks ({:.2}x saving)",
        sharing.blocks_peak,
        nosharing.blocks_peak,
        nosharing.blocks_peak as f64 / sharing.blocks_peak.max(1) as f64
    );

    // --- machine-readable report for the CI regression gate ----------
    if let Ok(path) = std::env::var("ESACT_BENCH_JSON") {
        let join = |cells: &[Cell]| cells.iter().map(Cell::json).collect::<Vec<_>>().join(",\n      ");
        let mut out = String::from("{\n  \"schema\": 6,\n  \"paged\": {\n");
        let _ = writeln!(out, "    \"pool_blocks\": {POOL_BLOCKS},");
        let _ = writeln!(out, "    \"block_size\": {BLOCK_SIZE},");
        let _ = writeln!(out, "    \"prefix_len\": {PREFIX_LEN},");
        let _ = writeln!(out, "    \"cells\": [\n      {}\n    ],", join(&cells));
        let _ = writeln!(out, "    \"prefix_hit_rate\": {hit_rate:.4},");
        let _ = writeln!(
            out,
            "    \"cow\": {{\"sessions\": {cow_sessions}, \"prefix_len\": 50, \
             \"cow_copies\": {}, \"shared_tokens\": {}}},",
            cow_wave.stats.cow_copies, cow_wave.stats.shared_attach_tokens
        );
        let _ = writeln!(
            out,
            "    \"sharing\": {{\"sessions\": {share_sessions}, \
             \"sharing_blocks_peak\": {}, \"nosharing_blocks_peak\": {}}}",
            sharing.blocks_peak, nosharing.blocks_peak
        );
        out.push_str("  }\n}\n");
        std::fs::write(&path, out)?;
        println!("\nwrote {path}");
    }
    Ok(())
}
