//! Decode-tier benchmarks: tokens/sec vs prefix length vs KV budget,
//! dense-cache vs evicting-cache, plus the step-plan-cache replay
//! speedup. Emits the machine-readable `BENCH_3.json` report (set
//! `ESACT_BENCH_JSON`) that `scripts/bench_gate.py` gates against the
//! committed `bench_baseline.json`: absolute tokens/sec floors per
//! cell, and the headline check that evicting-cache decode beats
//! dense-cache decode at prefix ≥ 64.

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

use esact::config::SplsConfig;
use esact::decode::{DecodeConfig, DecodeEngine, DecodeMode, GenSession, Sampling};
use esact::model::{self, TinyWeights};
use esact::spls::SharedPlanCache;
use esact::util::rng::Xoshiro256pp;

const NEW_TOKENS: usize = 32;
const REPS: usize = 3;

struct Cell {
    label: &'static str,
    prefix: usize,
    /// 0 encodes "unbounded" in the report.
    kv_budget: usize,
    tokens_per_sec: f64,
    ms_per_token: f64,
}

impl Cell {
    fn print(&self) {
        println!(
            "  {:<6} prefix {:>3} budget {:>3}: {:>8.0} tok/s ({:.3} ms/token)",
            self.label,
            self.prefix,
            if self.kv_budget == 0 { "∞".to_string() } else { self.kv_budget.to_string() },
            self.tokens_per_sec,
            self.ms_per_token
        );
    }

    fn json(&self) -> String {
        format!(
            "{{\"mode\": \"{}\", \"prefix\": {}, \"kv_budget\": {}, \
             \"tokens_per_sec\": {:.2}, \"ms_per_token\": {:.4}}}",
            self.label, self.prefix, self.kv_budget, self.tokens_per_sec, self.ms_per_token
        )
    }
}

fn prompt_for(base: &[i32], prefix: usize) -> Vec<i32> {
    (0..prefix).map(|i| base[i % base.len()]).collect()
}

/// Best-of-REPS generation throughput: prefill `prefix` prompt tokens,
/// then time `NEW_TOKENS` greedy decode steps.
fn run_cell(
    engine: &Arc<DecodeEngine>,
    base: &[i32],
    label: &'static str,
    mode: DecodeMode,
    budget: usize,
    prefix: usize,
    cache: Option<&SharedPlanCache>,
) -> Cell {
    let mut best = 0.0f64;
    for _ in 0..REPS {
        let cfg = DecodeConfig { mode, kv_budget: budget, recent: 4, spls: SplsConfig::default() };
        let mut s = GenSession::new(
            Arc::clone(engine),
            cfg,
            prompt_for(base, prefix),
            NEW_TOKENS,
            Sampling::Greedy,
        );
        if let Some(c) = cache {
            s = s.with_plan_cache(c.clone());
        }
        let consumed = s.run_steps(prefix); // prefill only
        assert!(consumed.is_empty(), "prefill slice must not generate");
        let t0 = Instant::now();
        let out = s.run_steps(NEW_TOKENS + 1);
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(out.len(), NEW_TOKENS);
        best = best.max(NEW_TOKENS as f64 / dt.max(1e-12));
    }
    Cell {
        label,
        prefix,
        kv_budget: if budget == usize::MAX { 0 } else { budget },
        tokens_per_sec: best,
        ms_per_token: 1e3 / best.max(1e-12),
    }
}

fn main() -> anyhow::Result<()> {
    let dir = esact::util::artifacts_dir();
    let weights = Arc::new(TinyWeights::load(&dir.join("tiny_weights.bin"))?);
    let engine = Arc::new(DecodeEngine::new(weights));
    let mut rng = Xoshiro256pp::new(11);
    let (base, _) = model::synth::gen_example(&mut rng, 64);

    // --- dense-cache vs evicting-cache across prefix lengths ---------
    println!("== decode throughput: dense cache vs evicting cache (32 new tokens) ==");
    let mut decode_cells: Vec<Cell> = Vec::new();
    let mut versus: Vec<(usize, f64, f64)> = Vec::new();
    for prefix in [16usize, 64, 96] {
        let dense =
            run_cell(&engine, &base, "dense", DecodeMode::Dense, usize::MAX, prefix, None);
        let evict = run_cell(&engine, &base, "evict", DecodeMode::Spls, 32, prefix, None);
        dense.print();
        evict.print();
        versus.push((prefix, dense.tokens_per_sec, evict.tokens_per_sec));
        decode_cells.push(dense);
        decode_cells.push(evict);
    }
    for &(prefix, d, e) in &versus {
        let verdict = if e > d { "evict wins ✓" } else { "dense wins ✗" };
        println!(
            "  prefix {prefix:>3}: evict/dense = {:.2}x  ({verdict})",
            e / d.max(1e-12)
        );
    }

    // --- KV-budget sweep at prefix 64 --------------------------------
    println!("\n== evicting-cache budget sweep (prefix 64) ==");
    let mut sweep_cells: Vec<Cell> = Vec::new();
    for budget in [16usize, 32, 48] {
        let cell = run_cell(&engine, &base, "evict", DecodeMode::Spls, budget, 64, None);
        cell.print();
        sweep_cells.push(cell);
    }

    // --- step-plan-cache replay --------------------------------------
    println!("\n== step-plan-cache replay (prefix 64, budget 32) ==");
    let cache = SharedPlanCache::new(1024);
    let timed_session = |cache: &SharedPlanCache| -> f64 {
        let cfg = DecodeConfig {
            mode: DecodeMode::Spls,
            kv_budget: 32,
            recent: 4,
            spls: SplsConfig::default(),
        };
        let mut s = GenSession::new(
            Arc::clone(&engine),
            cfg,
            prompt_for(&base, 64),
            NEW_TOKENS,
            Sampling::Greedy,
        )
        .with_plan_cache(cache.clone());
        s.run_steps(64);
        let t0 = Instant::now();
        let out = s.run_steps(NEW_TOKENS + 1);
        assert_eq!(out.len(), NEW_TOKENS);
        NEW_TOKENS as f64 / t0.elapsed().as_secs_f64().max(1e-12)
    };
    let cold_tps = timed_session(&cache); // populates the step cache
    let warm_tps = timed_session(&cache); // replays it
    println!(
        "  cold {:>8.0} tok/s → warm {:>8.0} tok/s ({:.2}x) | step cache {:.0}% hit",
        cold_tps,
        warm_tps,
        warm_tps / cold_tps.max(1e-12),
        cache.stats().step_hit_rate() * 100.0
    );

    // --- machine-readable report for the CI regression gate ----------
    if let Ok(path) = std::env::var("ESACT_BENCH_JSON") {
        let join =
            |cells: &[Cell]| cells.iter().map(Cell::json).collect::<Vec<_>>().join(",\n    ");
        let mut out = String::from("{\n  \"schema\": 3,\n");
        let _ = writeln!(out, "  \"decode\": [\n    {}\n  ],", join(&decode_cells));
        let _ = writeln!(out, "  \"budget_sweep\": [\n    {}\n  ],", join(&sweep_cells));
        let vs = versus
            .iter()
            .map(|&(prefix, d, e)| {
                format!(
                    "{{\"prefix\": {prefix}, \"dense_tps\": {d:.2}, \"evict_tps\": {e:.2}, \
                     \"speedup\": {:.4}}}",
                    e / d.max(1e-12)
                )
            })
            .collect::<Vec<_>>()
            .join(",\n    ");
        let _ = writeln!(out, "  \"evict_vs_dense\": [\n    {vs}\n  ],");
        let _ = writeln!(
            out,
            "  \"plan_replay\": {{\"cold_tps\": {cold_tps:.2}, \"warm_tps\": {warm_tps:.2}, \
             \"step_hit_rate\": {:.3}}}",
            cache.stats().step_hit_rate()
        );
        out.push_str("}\n");
        std::fs::write(&path, out)?;
        println!("\nwrote {path}");
    }
    Ok(())
}
