//! Streaming generation from the tiny model: prefill a synthetic
//! prompt, then stream tokens one by one through the decode engine,
//! dense-cache or evicting-cache.
//!
//! ```bash
//! cargo run --release --example generate_tiny -- [prefix] [max_new] [--kv-budget B]
//! # dense, unbounded cache:
//! cargo run --release --example generate_tiny -- 32 24
//! # incremental-SPLS decode with a 16-slot per-head KV budget:
//! cargo run --release --example generate_tiny -- 32 24 --kv-budget 16
//! ```

use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

use esact::config::SplsConfig;
use esact::decode::{generate, DecodeConfig, DecodeEngine, DecodeMode, Sampling};
use esact::model::{self, TinyWeights};
use esact::util::rng::Xoshiro256pp;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut pos: Vec<&String> = Vec::new();
    let mut kv_budget = usize::MAX;
    let mut i = 0usize;
    while i < args.len() {
        if args[i] == "--kv-budget" {
            kv_budget =
                args.get(i + 1).and_then(|s| s.parse().ok()).unwrap_or(usize::MAX);
            i += 2;
        } else {
            pos.push(&args[i]);
            i += 1;
        }
    }
    let prefix: usize = pos.first().and_then(|s| s.parse().ok()).unwrap_or(32);
    let max_new: usize = pos.get(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    if kv_budget != usize::MAX {
        kv_budget = kv_budget.max(2); // finite budgets need ≥ 2 slots
    }

    let dir = esact::util::artifacts_dir();
    let weights = Arc::new(TinyWeights::load(&dir.join("tiny_weights.bin"))?);
    let engine = Arc::new(DecodeEngine::new(weights));
    let mut rng = Xoshiro256pp::new(42);
    let (base, _) = model::synth::gen_example(&mut rng, 64);
    let prompt: Vec<i32> = (0..prefix.max(1)).map(|j| base[j % base.len()]).collect();

    // a finite budget switches on the incremental-SPLS gated path
    let mode = if kv_budget == usize::MAX { DecodeMode::Dense } else { DecodeMode::Spls };
    let cfg = DecodeConfig { mode, kv_budget, recent: 4, spls: SplsConfig::default() };

    println!(
        "prompt {} tokens, generating {max_new} ({mode:?}, kv budget {})…",
        prompt.len(),
        if kv_budget == usize::MAX { "∞".to_string() } else { kv_budget.to_string() }
    );
    let t0 = Instant::now();
    let res = generate(&engine, cfg, &prompt, max_new, Sampling::Greedy, |_, t| {
        print!("{t} ");
        std::io::stdout().flush().ok();
    });
    let dt = t0.elapsed().as_secs_f64();
    println!();
    let s = res.stats;
    println!(
        "{} tokens in {:.1} ms ({:.0} tok/s incl. prefill) | {} steps, {} similar \
         head-steps, {} FFN reuses, {} evictions",
        res.tokens.len(),
        dt * 1e3,
        res.tokens.len() as f64 / dt.max(1e-9),
        s.steps,
        s.sim_heads,
        s.ffn_skips,
        s.evictions
    );
    Ok(())
}
