//! End-to-end serving driver (the repo's E2E validation workload, see
//! DESIGN.md §Serving coordinator): load the tiny classifier artifacts, serve a
//! Poisson stream of test-set requests through the replicated
//! coordinator (admission → continuous batcher → work-stealing replica
//! tier → executors), in dense and SPLS modes, and report accuracy,
//! latency percentiles, throughput per replica, and plan-cache hit
//! rate.
//!
//! ```bash
//! cargo run --release --example serve_tiny [n_requests] [replicas] [gen|http]
//! # third arg "gen" additionally streams a generation workload through
//! # Server::serve_generate (continuous decode batching, SPLS eviction);
//! # third arg "http" skips the offline runs and starts the curl-able
//! # network gateway instead (make serve-http):
//! #   curl localhost:8080/healthz
//! #   curl -X POST localhost:8080/admin/shutdown   # graceful drain
//! ```

use std::sync::mpsc;
use std::time::Instant;

use esact::config::SplsConfig;
use esact::coordinator::server::Mode;
use esact::coordinator::{BatchPolicy, GenRequest, Request, Server};
use esact::decode::{DecodeConfig, DecodeMode, Sampling};
use esact::model::{self, TestSet};
use esact::util::rng::Xoshiro256pp;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(128);
    let replicas: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(2);
    let mode_arg = std::env::args().nth(3).unwrap_or_default();
    let with_gen = mode_arg == "gen";
    let dir = &esact::util::artifacts_dir();
    let set = TestSet::load(&dir.join("tiny_testset.bin"))?;

    if mode_arg == "http" {
        // network mode: put the SPLS tier on a socket and serve until
        // POST /admin/shutdown (or Ctrl-C)
        use esact::net::{Gateway, GatewayConfig};
        let srv = std::sync::Arc::new(Server::new(dir, Mode::Spls, SplsConfig::default())?);
        let cfg = GatewayConfig::builder()
            .addr(
                std::env::var("ESACT_HTTP_ADDR")
                    .unwrap_or_else(|_| "127.0.0.1:8080".to_string()),
            )
            .replicas(replicas)
            .mode(Mode::Spls)
            .build()?;
        let l = srv.seq_len();
        let gateway = Gateway::start(srv, cfg)?;
        let addr = gateway.local_addr();
        println!("tiny ESACT gateway on http://{addr} ({replicas} replicas, SPLS mode)");
        println!("try:");
        println!(
            "  curl -s -X POST http://{addr}/v1/classify -d \
             '{{\"tokens\": [[{}]]}}'",
            (0..l).map(|i| (i % 64).to_string()).collect::<Vec<_>>().join(", ")
        );
        println!(
            "  curl -sN -X POST http://{addr}/v1/generate -d \
             '{{\"prompt\": [1, 2, 3, 4], \"max_new\": 8}}'"
        );
        println!("  curl -s http://{addr}/metrics | head");
        println!("  curl -s -X POST http://{addr}/admin/shutdown");
        let report = gateway.join()?;
        print!("{report}");
        return Ok(());
    }

    for mode in [Mode::Dense, Mode::Spls] {
        let srv = Server::new(dir, mode, SplsConfig::default())?;
        let (tx, rx) = mpsc::channel::<Request>();
        let (rtx, rrx) = mpsc::channel::<esact::coordinator::Reply>();

        // producer: replay the held-out test set as requests
        let reqs: Vec<Request> = (0..n)
            .map(|i| Request {
                id: i as u64,
                tokens: set.tokens[i % set.len()].clone(),
                arrived: Instant::now(),
            })
            .collect();
        let labels: Vec<i32> = (0..n).map(|i| set.labels[i % set.len()]).collect();
        // Poisson arrivals at ~2× the SPLS-mode service rate exercise
        // the batcher under realistic load (coordinator::loadgen)
        let producer = std::thread::spawn(move || {
            let mut rng = Xoshiro256pp::new(1);
            let trace = esact::coordinator::arrivals(
                &mut rng,
                esact::coordinator::Arrival::Poisson { rate: 500.0 },
                reqs.len(),
            );
            let start = Instant::now();
            for (mut r, at) in reqs.into_iter().zip(trace) {
                if let Some(wait) = at.0.checked_sub(start.elapsed()) {
                    std::thread::sleep(wait);
                }
                r.arrived = Instant::now();
                if tx.send(r).is_err() {
                    break;
                }
            }
        });
        let collector = std::thread::spawn(move || {
            let mut correct = 0usize;
            let mut total = 0usize;
            for reply in rrx.iter() {
                let pred = model::tensor::argmax(&reply.logits) as i32;
                correct += usize::from(pred == labels[reply.id as usize]);
                total += 1;
            }
            (correct, total)
        });

        let outcome = srv.serve_replicated(rx, rtx, BatchPolicy::default(), replicas)?;
        producer.join().unwrap();
        let (correct, total) = collector.join().unwrap();
        let metrics = outcome.metrics;

        println!(
            "{mode:?} x{replicas}: {total} replies | accuracy {:.4} | {} batches, {} padded, \
             {} stolen, {} shed | latency p50 {:.2} ms p99 {:.2} ms (max {:.2}) | \
             {:.0} req/s ({:.0}/replica) | plan cache {:.0}% hit",
            correct as f64 / total.max(1) as f64,
            metrics.batches,
            metrics.padded_slots,
            metrics.steals,
            metrics.shed,
            metrics.p50_latency.as_secs_f64() * 1e3,
            metrics.p99_latency.as_secs_f64() * 1e3,
            metrics.max_latency.as_secs_f64() * 1e3,
            metrics.throughput_rps(),
            metrics.throughput_per_replica(),
            metrics.plan_cache.hit_rate() * 100.0
        );
        for r in &outcome.per_replica {
            println!(
                "  replica {}: {} batches / {} requests ({} stolen), busy {:.1} ms",
                r.replica,
                r.batches,
                r.requests,
                r.steals,
                r.busy.as_secs_f64() * 1e3
            );
        }
    }

    if with_gen {
        // generation workload: test-set prompts streamed through the
        // decode tier with SPLS-scored KV eviction
        let sessions = (n / 8).clamp(2, 16);
        let max_new = 16usize;
        let srv = Server::new(dir, Mode::Spls, SplsConfig::default())?;
        let decode = DecodeConfig {
            mode: DecodeMode::Spls,
            kv_budget: 24,
            recent: 4,
            spls: SplsConfig::default(),
        };
        let (tx, rx) = mpsc::channel();
        let (ctx, crx) = mpsc::channel();
        for i in 0..sessions {
            tx.send(GenRequest {
                id: i as u64,
                prompt: set.tokens[i % set.len()][..24].to_vec(),
                prefix: None,
                max_new,
                sampling: Sampling::TopK { k: 4, temperature: 1.0, seed: i as u64 },
                arrived: Instant::now(),
            })?;
        }
        drop(tx);
        let drain = std::thread::spawn(move || {
            let (mut chunks, mut tokens) = (0usize, 0usize);
            for c in crx.iter() {
                chunks += 1;
                tokens += c.tokens.len();
            }
            (chunks, tokens)
        });
        let outcome = srv.serve_generate(rx, ctx, decode, replicas, 6)?;
        let (chunks, tokens) = drain.join().unwrap();
        let m = outcome.metrics;
        println!(
            "generate x{replicas}: {} sessions, {tokens} tokens in {chunks} chunks | \
             {:.0} tok/s | {} slices ({} stolen) | session p50 {:.1} ms p99 {:.1} ms | \
             step cache {:.0}% hit",
            m.sessions,
            m.tokens_per_sec(),
            m.slices,
            m.steals,
            m.p50_session.as_secs_f64() * 1e3,
            m.p99_session.as_secs_f64() * 1e3,
            m.plan_cache.step_hit_rate() * 100.0
        );
    }
    Ok(())
}
