//! Quickstart: the SPLS pipeline end to end on one sequence.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Walks the paper's Fig 5(a) flow: HLog attention prediction through
//! the bit-level unit model → top-k SPA → windowed local similarity →
//! Q/KV/FFN sparsification → sparse forward with recovery → and the
//! same masks through the AOT-compiled PJRT executable.

use esact::config::SplsConfig;
use esact::model::{self, TinyWeights};
use esact::quant::QuantMethod;
use esact::runtime::{Arg, ArtifactSet};
use esact::util::rng::Xoshiro256pp;

fn main() -> anyhow::Result<()> {
    let dir = &esact::util::artifacts_dir();
    let weights = TinyWeights::load(&dir.join("tiny_weights.bin"))?;
    let spls = SplsConfig::default();
    println!("SPLS config: {spls:?}\n");

    // a synthetic sequence with local token similarity
    let mut rng = Xoshiro256pp::new(7);
    let (tokens, label) = model::synth::gen_example(&mut rng, weights.cfg.seq_len);
    println!("sequence of {} tokens, true label {label}", tokens.len());

    // 1. predict sparsity on real activations (bit-level unit model)
    let plans = model::plan_model(&weights, &tokens, &spls, QuantMethod::Hlog);
    for (i, p) in plans.iter().enumerate() {
        println!(
            "layer {i}: Q sparsity {:.3} | KV {:.3} | attention {:.3} | FFN {:.3}",
            p.q_sparsity(),
            p.kv_sparsity(),
            p.attn_sparsity(),
            p.ffn_sparsity()
        );
    }

    // 2. dense vs SPLS-sparse forward on the host
    let dense = model::forward_dense(&weights, &tokens);
    let sparse = model::forward_sparse(&weights, &tokens, &plans);
    let argmax = |v: &[f32]| esact::model::tensor::argmax(v);
    println!(
        "\nhost dense  → class {} | host SPLS → class {}",
        argmax(&dense),
        argmax(&sparse)
    );

    // 3. the same masks through the AOT PJRT executable (serve path)
    let artifacts = ArtifactSet::load(dir)?;
    let l = weights.cfg.seq_len;
    let mut masks = Vec::new();
    for p in &plans {
        for h in &p.heads {
            for r in 0..l {
                let src = h.sim.rep[r];
                for c in 0..l {
                    masks.push(if h.mask[(src, c)] { 1.0f32 } else { 0.0 });
                }
            }
        }
    }
    let logits = artifacts.masked_b1.run_f32(&[
        Arg::I32(&tokens, &[1, l]),
        Arg::F32(&masks, &[1, 2, 4, l, l]),
    ])?;
    println!("AOT masked  → class {} (PJRT, python-free)", argmax(&logits));

    // the FLOP ledger
    let cfg = esact::config::ModelConfig::new("tiny", l, 64, 4, 2, 256, false);
    let (overall, qkv, attn, ffn) = esact::spls::computation_reduction(&cfg, &plans);
    println!(
        "\ncomputation reduction: overall {:.1}% (QKV {:.1}%, attention {:.1}%, FFN {:.1}%)",
        100.0 * overall,
        100.0 * qkv,
        100.0 * attn,
        100.0 * ffn
    );
    Ok(())
}
