//! Sparsity-accuracy frontier explorer: grid-search the SPLS
//! hyperparameters (k, s, f) on the tiny substrate and print the
//! Pareto frontier — the tool behind the paper's §V-B methodology
//! ("fine-grained grid search over the (s, f) space ... retain those
//! in which the performance degradation remains within 1%").
//!
//! ```bash
//! cargo run --release --example sparsity_explorer [n_eval]
//! ```

use esact::config::SplsConfig;
use esact::model::{self, TestSet, TinyWeights};
use esact::quant::QuantMethod;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(48);
    let dir = &esact::util::artifacts_dir();
    let w = TinyWeights::load(&dir.join("tiny_weights.bin"))?;
    let set = TestSet::load(&dir.join("tiny_testset.bin"))?;
    let dense = model::eval_dense(&w, &set, n);
    println!("dense accuracy {:.4} over {n} sequences\n", dense.accuracy);

    let mut frontier: Vec<(f64, f64, SplsConfig)> = Vec::new(); // (reduction, loss, cfg)
    for k in [0.1f32, 0.12, 0.15, 0.2] {
        for s in [0.2f32, 0.4, 0.6, 0.8] {
            for f in [2usize, 3] {
                let spls = SplsConfig { top_k: k, sim_threshold: s, ffn_threshold: f, window: 8 };
                let r = model::eval_sparse(&w, &set, n, &spls, QuantMethod::Hlog);
                // rough reduction proxy from measured component sparsity
                let reduction = 0.3 * (r.q_sparsity + r.kv_sparsity) / 2.0
                    + 0.1 * r.attn_sparsity
                    + 0.6 * r.ffn_sparsity;
                let loss = r.loss_vs(&dense);
                let tag = if loss <= 1.0 { "≤1% ✓" } else { "      " };
                println!(
                    "k={k:.2} s={s:.1} f={f}: acc {:.4} (loss {loss:+.2}) | \
                     Q {:.2} KV {:.2} attn {:.2} FFN {:.2} | est. reduction {:.1}% {tag}",
                    r.accuracy,
                    r.q_sparsity,
                    r.kv_sparsity,
                    r.attn_sparsity,
                    r.ffn_sparsity,
                    100.0 * reduction
                );
                if loss <= 1.0 {
                    frontier.push((reduction, loss, spls));
                }
            }
        }
    }

    frontier.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    println!("\nbest loss ≤ 1% operating points:");
    for (red, loss, cfg) in frontier.iter().take(5) {
        println!(
            "  k={:.2} s={:.1} f={} → est. reduction {:.1}% at {:+.2} pts",
            cfg.top_k,
            cfg.sim_threshold,
            cfg.ffn_threshold,
            100.0 * red,
            loss
        );
    }
    Ok(())
}
