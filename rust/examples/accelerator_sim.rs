//! Accelerator design-space exploration: sweep the cycle-level ESACT
//! simulator across the paper's model zoo and across hardware variants
//! (PE array shape, window size), printing the mechanism waterfall for
//! each — the tool an architect would use to re-evaluate the paper's
//! design choices on a new workload.
//!
//! ```bash
//! cargo run --release --example accelerator_sim
//! ```

use esact::config::{self, HardwareConfig, SplsConfig};
use esact::sim::{ablation, simulate_model, Features};
use esact::workloads::bench26::SparsityProfile;

fn main() {
    let spls = SplsConfig::default();
    let profile = SparsityProfile { q: 0.6, kv: 0.6, attn: 0.946, ffn: 0.5 };
    let models = [
        config::bert_base(128),
        config::bert_base(512),
        config::bert_large(512),
        config::gpt2(512),
        config::vit_b16(),
    ];

    println!("== mechanism waterfall per model (paper Fig 20 shape) ==");
    let hw = HardwareConfig::default();
    for cfg in &models {
        let [d, s, p, f] = ablation(cfg, &hw, &spls, &profile);
        println!(
            "{:>11} L={:<4} dense {:>9.3} ms | SPLS ×{:.2} | +prog ×{:.2} | +dyn ×{:.2} | util {:.2} | {:.2} TOPS/W",
            cfg.name,
            cfg.seq_len,
            d.seconds(&hw) * 1e3,
            d.cycles as f64 / s.cycles as f64,
            s.cycles as f64 / p.cycles as f64,
            p.cycles as f64 / f.cycles as f64,
            f.pe_utilization(&hw),
            f.tops_per_watt(&hw),
        );
    }

    println!("\n== PE-array shape ablation (BERT-Base, L=128) ==");
    let cfg = config::bert_base(128);
    for (rows, cols) in [(8usize, 128usize), (16, 64), (32, 32), (64, 16)] {
        let hw = HardwareConfig { pe_rows: rows, pe_cols: cols, ..HardwareConfig::default() };
        let r = simulate_model(&cfg, &hw, &spls, &profile, Features::FULL);
        println!(
            "  {rows:>2}×{cols:<3} {:>9} cycles | util {:.3} | {:.2} TOPS/W",
            r.cycles,
            r.pe_utilization(&hw),
            r.tops_per_watt(&hw),
        );
    }

    println!("\n== window-size ablation (similarity cost vs coverage) ==");
    for w in [2usize, 4, 8, 16, 32] {
        let spls_w = SplsConfig { window: w, ..spls };
        let hw = HardwareConfig::default();
        let r = simulate_model(&cfg, &hw, &spls_w, &profile, Features::FULL);
        let cmp = esact::workloads::flops::local_similarity_comparisons(128, w);
        println!(
            "  w={w:<3} {:>9} cycles | sim comparisons/layer {cmp:>6} | {:.2} TOPS/W",
            r.cycles,
            r.tops_per_watt(&hw),
        );
    }
}
