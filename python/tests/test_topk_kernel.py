"""Top-k Pallas kernel vs reference mask semantics."""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.topk import topk_mask


@settings(max_examples=20, deadline=None)
@given(
    l=st.sampled_from([8, 16, 64]),
    k=st.sampled_from([0.05, 0.12, 0.25, 0.5, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_on_continuous_scores(l, k, seed):
    # continuous scores -> no ties -> kernel ≡ ref.topk_mask exactly
    rng = np.random.default_rng(seed)
    scores = rng.standard_normal((l, l)).astype(np.float32)
    got = np.asarray(topk_mask(scores, k))
    want = np.asarray(ref.topk_mask(jnp.asarray(scores), k))
    np.testing.assert_array_equal(got, want)


def test_tie_handling_keeps_at_least_k():
    # integer ties: the threshold form may keep more than k, never fewer
    pam = np.ones((8, 8), np.float32) * 5.0
    m = np.asarray(topk_mask(pam, 0.25))
    assert (m.sum(-1) >= 2).all()  # keep = 2
    # all-equal rows keep everything under threshold semantics
    assert (m == 1.0).all()


def test_keeps_row_maxima():
    rng = np.random.default_rng(7)
    scores = rng.standard_normal((32, 32)).astype(np.float32)
    m = np.asarray(topk_mask(scores, 0.1))
    amax = scores.argmax(-1)
    assert m[np.arange(32), amax].all()


def test_full_ratio_keeps_all():
    rng = np.random.default_rng(9)
    scores = rng.standard_normal((16, 16)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(topk_mask(scores, 1.0)), 1.0)


def test_block_invariance():
    rng = np.random.default_rng(13)
    scores = rng.standard_normal((64, 64)).astype(np.float32)
    base = np.asarray(topk_mask(scores, 0.12, bl=64))
    for bl in (8, 16, 32):
        np.testing.assert_array_equal(np.asarray(topk_mask(scores, 0.12, bl=bl)), base)
