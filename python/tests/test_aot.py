"""AOT-export path tests: HLO text round-trips with constants intact.

Regression coverage for the elided-constants bug: `as_hlo_text()`
defaults to printing large constants as `{...}`, which the XLA text
parser silently reads back as zeros — the deployed model would serve
garbage while every python-side test stays green.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from compile.aot import to_hlo_text


def _lower(fn, *specs):
    return jax.jit(fn).lower(*specs)


def test_large_constants_not_elided():
    w = np.arange(256 * 256, dtype=np.float32).reshape(256, 256)
    lowered = _lower(
        lambda x: (x @ w,), jax.ShapeDtypeStruct((4, 256), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert "{...}" not in text, "large constants were elided"
    # a known interior value must appear verbatim in the text
    assert "65535" in text


def test_hlo_text_is_parseable_entry_module():
    lowered = _lower(
        lambda x: (x * 2.0 + 1.0,), jax.ShapeDtypeStruct((8,), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True -> tuple-shaped root
    assert "(f32[8]" in text.replace("{1,0}", "")


def test_pallas_kernel_lowers_to_plain_hlo():
    # interpret=True Pallas must lower to ordinary HLO ops (no custom
    # calls the CPU PJRT client can't run)
    from compile.kernels.hlog import hlog_matmul

    spec = jax.ShapeDtypeStruct((64, 64), jnp.int32)
    lowered = _lower(lambda x, w: (hlog_matmul(x, w),), spec, spec)
    text = to_hlo_text(lowered)
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
    assert "dot(" in text or "dot." in text or "dot " in text
