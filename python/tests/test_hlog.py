"""L1 HLog kernel correctness: Pallas kernel vs pure-jnp reference.

The HLog path is an exact-integer contract (paper §III-A/IV-B): the
Pallas kernel, the reference, and the rust bit-level model must agree
bit-for-bit on every int8 input.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.hlog import hlog_matmul, hlog_quantize, int8_matmul


# ---------------------------------------------------------------------------
# Level-set semantics
# ---------------------------------------------------------------------------


def test_hlog_levels_structure():
    # {2^0, 2^1, 2^0+2^1, 2^2, ..., 2^{n-2}, 2^{n-3}+2^{n-2}, 2^{n-1}}
    lv = ref.hlog_levels(8)
    assert lv == [1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128]
    # every power of two present
    for m in range(8):
        assert 2**m in lv
    # every midpoint 3*2^{m-1} for 1 <= m <= 6 present
    for m in range(1, 7):
        assert 2**m + 2 ** (m - 1) in lv


def test_pot_apot_levels():
    assert ref.pot_levels(8) == [1, 2, 4, 8, 16, 32, 64, 128]
    apot = ref.apot_levels(8)
    # APoT(a=2) contains all PoT levels plus all pairwise sums < 256
    assert set(ref.pot_levels(8)) <= set(apot)
    assert 3 in apot and 192 in apot
    assert len(apot) > len(ref.hlog_levels(8)) > len(ref.pot_levels(8))


def _nearest_ties_up(a: int, levels: list[int]) -> int:
    best = min(levels, key=lambda lv: (abs(a - lv), -lv))
    return best


@pytest.mark.parametrize("x", list(range(-255, 256)))
def test_hlog_quantize_nearest_level_exhaustive(x):
    """Every int in [-255, 255] projects to the nearest HLog level (ties up)."""
    got = int(np.asarray(ref.hlog_quantize(jnp.asarray([x], jnp.int32)))[0])
    if x == 0:
        assert got == 0
        return
    lv = ref.hlog_levels(9 if abs(x) > 128 else 8)
    # quantizer operates on magnitude with the leading-one detector, so
    # the level set extends naturally beyond 128 for 9-bit magnitudes.
    want = int(np.sign(x)) * _nearest_ties_up(abs(x), lv)
    assert got == want, f"x={x}: got {got}, want {want}"


def test_hlog_code_planes():
    xs = jnp.asarray([0, 1, -1, 2, 3, 5, -6, 127, -128, 42], jnp.int32)
    sign, e, form = ref.hlog_code(xs)
    q = ref.hlog_quantize(xs)
    mag = np.where(
        np.asarray(form) == 1,
        3 * (1 << np.maximum(np.asarray(e) - 1, 0)),
        1 << np.asarray(e),
    )
    reconstructed = np.asarray(sign) * np.where(np.asarray(xs) == 0, 0, mag)
    np.testing.assert_array_equal(reconstructed, np.asarray(q))


def test_kernel_quantize_matches_ref_exhaustive():
    xs = jnp.arange(-255, 256, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(hlog_quantize(xs)), np.asarray(ref.hlog_quantize(xs))
    )


# ---------------------------------------------------------------------------
# Matmul kernels vs reference (bit-exact integer path)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 2, 3, 8, 16, 64]),
    k=st.sampled_from([1, 4, 16, 64]),
    n=st.sampled_from([1, 2, 8, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hlog_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (m, k), dtype=np.int32)
    w = rng.integers(-128, 128, (k, n), dtype=np.int32)
    got = np.asarray(hlog_matmul(jnp.asarray(x), jnp.asarray(w)))
    want = np.asarray(ref.hlog_matmul(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([2, 8, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_int8_matmul_exact(m, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (m, 32), dtype=np.int32)
    w = rng.integers(-128, 128, (32, m), dtype=np.int32)
    got = np.asarray(int8_matmul(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, x.astype(np.int64) @ w.astype(np.int64))


def test_hlog_matmul_blocking_invariance():
    """Different BlockSpec tilings must produce identical results."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, (64, 64), dtype=np.int32))
    w = jnp.asarray(rng.integers(-128, 128, (64, 64), dtype=np.int32))
    base = np.asarray(hlog_matmul(x, w))
    for b in (8, 16, 32, 64):
        np.testing.assert_array_equal(
            np.asarray(hlog_matmul(x, w, bm=b, bn=b, bk=b)), base
        )


def test_predict_attention_pipeline():
    """Full PAM prediction (x -> HLog QK -> requant -> HLog attention)."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(-128, 128, (16, 32), dtype=np.int32))
    wq = jnp.asarray(rng.integers(-128, 128, (32, 8), dtype=np.int32))
    wk = jnp.asarray(rng.integers(-128, 128, (32, 8), dtype=np.int32))
    pam = np.asarray(ref.predict_attention(x, wq, wk))
    assert pam.shape == (16, 16)
    assert pam.dtype == np.int32
    # PAM magnitudes bounded by 127*127*Dh (requantized operands)
    assert np.abs(pam).max() <= 127 * 127 * 8


# ---------------------------------------------------------------------------
# Quantization error ordering (paper Fig 7: PoT worst, HLog ~ APoT)
# ---------------------------------------------------------------------------


def _mean_abs_err(quant_fn, xs):
    q = np.asarray(quant_fn(xs))
    return np.abs(q - np.asarray(xs)).mean()


def test_quant_error_ordering():
    xs = jnp.arange(1, 256, dtype=jnp.int32)
    e_pot = _mean_abs_err(ref.pot_quantize, xs)
    e_hlog = _mean_abs_err(ref.hlog_quantize, xs)
    e_apot = _mean_abs_err(ref.apot_quantize, xs)
    # PoT is by far the worst (paper Fig 6/7); HLog and APoT are close —
    # HLog even slightly better over the full int8 range despite far fewer
    # levels, because APoT's pairwise-sum levels thin out above 192.
    assert e_hlog < 0.6 * e_pot
    assert e_apot < 0.6 * e_pot
    assert abs(e_hlog - e_apot) < 0.2 * e_apot
