"""L2 model shape/numerics tests + ESWT container + data generator."""

from __future__ import annotations

import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from compile import data as dat
from compile import model as M
from compile.io import read_eswt, write_eswt
from compile.kernels import ref


CFG = M.TinyConfig()


def _params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_param_names_cover_init():
    p = _params()
    assert sorted(p.keys()) == sorted(M.param_names(CFG))


def test_forward_dense_shapes():
    p = _params()
    toks = jnp.zeros((CFG.seq_len,), jnp.int32)
    logits = M.forward_dense(p, toks, CFG)
    assert logits.shape == (CFG.n_classes,)
    assert np.isfinite(np.asarray(logits)).all()


def test_forward_masked_full_mask_equals_dense():
    p = M.quantize_params(_params())
    toks = jnp.asarray(np.arange(CFG.seq_len) % CFG.vocab, jnp.int32)
    masks = jnp.ones((CFG.n_layers, CFG.n_heads, CFG.seq_len, CFG.seq_len))
    d = np.asarray(M.forward_dense(p, toks, CFG, quant=False))
    m = np.asarray(M.forward_masked(p, toks, masks, CFG, quant=False))
    np.testing.assert_allclose(m, d, rtol=1e-4, atol=1e-4)


def test_attention_probs_rows_sum_to_one():
    p = _params()
    toks = jnp.asarray(np.arange(CFG.seq_len) % CFG.vocab, jnp.int32)
    probs = np.asarray(M.attention_probs(p, toks, CFG))
    assert probs.shape == (CFG.n_layers, CFG.n_heads, CFG.seq_len, CFG.seq_len)
    np.testing.assert_allclose(probs.sum(-1), 1.0, rtol=1e-5)


def test_fake_quant8_idempotent_and_grid():
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 32))
    q1 = M.fake_quant8(w)
    q2 = M.fake_quant8(q1)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-6)
    # values lie on a 255-level symmetric grid
    s = 127.0 / np.abs(np.asarray(q1)).max()
    grid = np.asarray(q1) * s
    np.testing.assert_allclose(grid, np.round(grid), atol=1e-3)


def test_quantize_params_only_matmul_weights():
    p = _params()
    qp = M.quantize_params(p)
    np.testing.assert_array_equal(np.asarray(p["embed"]), np.asarray(qp["embed"]))
    assert not np.array_equal(
        np.asarray(p["layer0.wq"]), np.asarray(qp["layer0.wq"])
    )


# ---------------------------------------------------------------------------
# ESWT container
# ---------------------------------------------------------------------------


def test_eswt_roundtrip():
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.asarray([-1, 0, 7], np.int32),
        "scalarish": np.asarray([3.5], np.float32),
        "tok": np.arange(6, dtype=np.uint16).reshape(2, 3),
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.bin")
        write_eswt(path, tensors)
        out = read_eswt(path)
    assert set(out) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].dtype == tensors[k].dtype


# ---------------------------------------------------------------------------
# Synthetic data generator (must be bit-exact with the rust mirror)
# ---------------------------------------------------------------------------


def test_xoshiro_known_sequence():
    """First few values from seed 42 — pinned so rust/src/util/rng.rs can
    assert the identical sequence."""
    rng = dat.Xoshiro256pp(42)
    got = [rng.next_u64() for _ in range(4)]
    assert all(0 <= v < 2**64 for v in got)
    rng2 = dat.Xoshiro256pp(42)
    assert got == [rng2.next_u64() for _ in range(4)]
    assert got != [dat.Xoshiro256pp(43).next_u64() for _ in range(4)]


def test_gen_example_structure():
    rng = dat.Xoshiro256pp(7)
    toks, label = dat.gen_example(rng, 64)
    assert toks.shape == (64,)
    assert (0 <= toks).all() and (toks < dat.N_CLUSTERS * dat.VARIANTS).all()
    assert 0 <= label < dat.N_CLUSTERS
    # label is the majority cluster
    clusters = toks // dat.VARIANTS
    counts = np.bincount(clusters, minlength=dat.N_CLUSTERS)
    assert label == int(np.argmax(counts))


def test_gen_batch_deterministic():
    a = dat.gen_batch(dat.Xoshiro256pp(123), 8, 32)
    b = dat.gen_batch(dat.Xoshiro256pp(123), 8, 32)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_runs_create_local_similarity():
    """Adjacent tokens share a cluster much more often than chance —
    the property SPLS exploits (paper §II-B)."""
    rng = dat.Xoshiro256pp(99)
    xs, _ = dat.gen_batch(rng, 64, 64)
    clusters = xs // dat.VARIANTS
    same_adj = (clusters[:, 1:] == clusters[:, :-1]).mean()
    assert same_adj > 0.5  # chance would be 1/16


# ---------------------------------------------------------------------------
# Requantization helper
# ---------------------------------------------------------------------------


def test_requantize_sym8():
    x = jnp.asarray([[-1000, 0, 250, 500, 1000]], jnp.int32)
    q, s = ref.requantize_sym8(x)
    q = np.asarray(q)
    assert q.min() >= -127 and q.max() <= 127
    assert q[0, 0] == -127 and q[0, 4] == 127 and q[0, 1] == 0
    assert abs(float(s) - 127.0 / 1000.0) < 1e-6
