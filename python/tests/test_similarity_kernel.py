"""Windowed-similarity Pallas kernel vs reference + rust-contract
semantics (greedy assignment identical to spls::similarity)."""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.similarity import greedy_assign, window_l1_distances


def _spa(rng, l, k_ratio=0.12):
    scores = rng.standard_normal((l, l)).astype(np.float32)
    mask = np.asarray(ref.topk_mask(jnp.asarray(scores), k_ratio))
    return (scores * 100).astype(np.int32).astype(np.float32) * mask


@settings(max_examples=15, deadline=None)
@given(
    l=st.sampled_from([16, 32, 64]),
    w=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_distances_match_numpy(l, w, seed):
    rng = np.random.default_rng(seed)
    spa = _spa(rng, l)
    dist, mass = window_l1_distances(spa, window=w)
    dist, mass = np.asarray(dist), np.asarray(mass)
    assert dist.shape == (l // w, w, w)
    for k in range(l // w):
        rows = spa[k * w : (k + 1) * w]
        want = np.abs(rows[:, None, :] - rows[None, :, :]).sum(-1)
        np.testing.assert_allclose(dist[k], want, rtol=1e-6)
        np.testing.assert_allclose(mass[k], np.abs(rows).sum(-1), rtol=1e-6)


def test_distance_properties():
    rng = np.random.default_rng(3)
    spa = _spa(rng, 32)
    dist, _ = window_l1_distances(spa, window=8)
    dist = np.asarray(dist)
    # symmetry + zero diagonal
    np.testing.assert_allclose(dist, dist.transpose(0, 2, 1), rtol=1e-6)
    for k in range(dist.shape[0]):
        np.testing.assert_allclose(np.diag(dist[k]), 0.0, atol=1e-6)


def test_greedy_assignment_semantics():
    # identical rows collapse; distinct rows stay critical
    spa = np.zeros((8, 8), np.float32)
    spa[0] = spa[1] = spa[3] = [1, 2, 3, 0, 0, 0, 0, 0]
    spa[2] = [9, 9, 9, 9, 0, 0, 0, 0]
    spa[4:] = np.eye(4, 8) * 50
    dist, mass = window_l1_distances(spa, window=8)
    rep = greedy_assign(dist, mass, threshold=0.0)
    assert rep[1] == 0 and rep[3] == 0
    assert rep[2] == 2
    assert all(rep[i] == i for i in range(4, 8))


def test_threshold_monotone():
    rng = np.random.default_rng(11)
    spa = _spa(rng, 64)
    dist, mass = window_l1_distances(spa, window=8)
    prev = 0
    for t in (0.0, 0.3, 0.6, 1.0, 2.0):
        rep = greedy_assign(dist, mass, t)
        n_sim = int((rep != np.arange(64)).sum())
        assert n_sim >= prev
        prev = n_sim


def test_windows_independent():
    # permuting other windows must not change window 0's distances
    rng = np.random.default_rng(5)
    spa = _spa(rng, 32)
    d1, _ = window_l1_distances(spa, window=8)
    spa2 = spa.copy()
    spa2[8:] = spa[8:][::-1]
    d2, _ = window_l1_distances(spa2, window=8)
    np.testing.assert_allclose(np.asarray(d1)[0], np.asarray(d2)[0], rtol=1e-6)
