"""L1 masked-attention kernel vs reference + SPA mask semantics."""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.sparse_attention import masked_attention


def _rand_qkv(rng, l, dh):
    return (
        rng.standard_normal((l, dh)).astype(np.float32),
        rng.standard_normal((l, dh)).astype(np.float32),
        rng.standard_normal((l, dh)).astype(np.float32),
    )


@settings(max_examples=20, deadline=None)
@given(
    l=st.sampled_from([4, 8, 16, 64]),
    dh=st.sampled_from([4, 8, 16]),
    k_ratio=st.sampled_from([0.1, 0.12, 0.25, 0.5, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_attention_matches_ref(l, dh, k_ratio, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _rand_qkv(rng, l, dh)
    scores = q @ k.T
    mask = np.asarray(ref.topk_mask(jnp.asarray(scores), k_ratio))
    got = np.asarray(masked_attention(q, k, v, mask))
    want = np.asarray(ref.masked_attention(q, k, v, mask))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_full_mask_equals_dense_softmax():
    rng = np.random.default_rng(3)
    q, k, v = _rand_qkv(rng, 16, 8)
    mask = np.ones((16, 16), np.float32)
    got = np.asarray(masked_attention(q, k, v, mask))
    s = (q @ k.T) / np.sqrt(8.0)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    np.testing.assert_allclose(got, p @ v, rtol=1e-5, atol=1e-5)


def test_single_position_mask():
    """Mask with one kept column per row -> output is exactly that V row."""
    rng = np.random.default_rng(5)
    q, k, v = _rand_qkv(rng, 8, 4)
    mask = np.zeros((8, 8), np.float32)
    cols = rng.integers(0, 8, 8)
    mask[np.arange(8), cols] = 1.0
    got = np.asarray(masked_attention(q, k, v, mask))
    np.testing.assert_allclose(got, v[cols], rtol=1e-5, atol=1e-6)


def test_topk_mask_row_counts():
    rng = np.random.default_rng(11)
    s = jnp.asarray(rng.standard_normal((32, 32)).astype(np.float32))
    for kr in (0.1, 0.12, 0.2, 0.5):
        m = np.asarray(ref.topk_mask(s, kr))
        keep = max(1, int(np.ceil(kr * 32)))
        np.testing.assert_array_equal(m.sum(-1), np.full(32, keep))
        # kept entries are the row maxima
        for r in range(32):
            kept_vals = np.asarray(s)[r][m[r] > 0]
            dropped = np.asarray(s)[r][m[r] == 0]
            if dropped.size:
                assert kept_vals.min() >= dropped.max() - 1e-6


def test_similar_row_replication_contract():
    """Rows sharing a critical row's mask AND Q produce identical outputs —
    the numerics contract behind ESACT's row-recovery (paper §III-C)."""
    rng = np.random.default_rng(9)
    q, k, v = _rand_qkv(rng, 8, 4)
    q[5] = q[2]  # row 5 is 'similar' to critical row 2: replicated Q
    mask = np.array(ref.topk_mask(jnp.asarray(q @ k.T), 0.5))  # writable copy
    mask[5] = mask[2]
    out = np.asarray(masked_attention(q, k, v, mask))
    np.testing.assert_allclose(out[5], out[2], rtol=1e-6)


def test_block_size_invariance():
    rng = np.random.default_rng(13)
    q, k, v = _rand_qkv(rng, 64, 16)
    mask = np.asarray(ref.topk_mask(jnp.asarray(q @ k.T), 0.2))
    base = np.asarray(masked_attention(q, k, v, mask, bl=64))
    for bl in (8, 16, 32):
        np.testing.assert_allclose(
            np.asarray(masked_attention(q, k, v, mask, bl=bl)), base, rtol=1e-6
        )
