"""Pure-jnp reference oracles for the ESACT L1 kernels.

Everything in this file is the *correctness contract*: the Pallas kernels
(`hlog.py`, `sparse_attention.py`) and the rust-side software model of the
bit-level prediction unit (`rust/src/spls/predict.rs`) must match these
functions bit-exactly (integer paths) or to float tolerance (softmax path).

The HLog quantization semantics follow paper §III-A / §IV-B exactly:

  levels(n)  = {2^0, 2^1, 2^0+2^1, 2^2, ..., 2^{n-2}, 2^{n-3}+2^{n-2}, 2^{n-1}}
  i.e. every power of two plus the midpoints 3·2^{m-1} between adjacent
  powers; ties round to the *higher* level.

The shift-detector bit rule (Fig 12): with I the index of the leading one
of |x| and (b1, b0) the two bits below it,

  form = b1 XOR b0          (1 -> sum form 2^e + 2^{e-1}, 0 -> single 2^e)
  e    = I + (b1 AND b0)    (pattern 11 rounds up to the next power)

which reproduces nearest-level-with-ties-up for every int8 input.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# HLog quantization
# ---------------------------------------------------------------------------


def hlog_levels(nbits: int = 8) -> list[int]:
    """The positive HLog quantization level set for an ``nbits`` input."""
    lv = []
    for m in range(nbits):
        lv.append(2**m)
        if 1 <= m <= nbits - 2:
            lv.append(2**m + 2 ** (m - 1))
    return sorted(set(lv))


def _floor_log2_u8(a):
    """Integer floor(log2(a)) for a in [1, 255], computed by comparisons.

    Comparison-count form is exact (no float log2 edge cases) and mirrors
    the leading-one detector of the hardware shift detector.
    """
    i = jnp.zeros_like(a)
    for t in (2, 4, 8, 16, 32, 64, 128):
        i = i + (a >= t).astype(a.dtype)
    return i


def hlog_quantize(x):
    """HLog-quantize an int8-valued array. Returns int32 levels (signed).

    Matches the shift detector: nearest HLog level, ties to the higher one.
    """
    x = jnp.asarray(x, jnp.int32)
    a = jnp.abs(x)
    sign = jnp.sign(x)
    i = _floor_log2_u8(jnp.maximum(a, 1))
    b1 = jnp.where(i >= 1, (a >> jnp.maximum(i - 1, 0)) & 1, 0)
    b0 = jnp.where(i >= 2, (a >> jnp.maximum(i - 2, 0)) & 1, 0)
    e = i + (b1 & b0)
    form = b1 ^ b0
    mag = jnp.where(form == 1, 3 * (1 << jnp.maximum(e - 1, 0)), 1 << e)
    return jnp.where(a == 0, 0, sign * mag)


def hlog_code(x):
    """The 5-bit shift-detector code (sign, e[3], form) as separate planes.

    Returns (sign, e, form) int32 arrays; ``sign`` in {-1, 0, +1}.
    Used by tests to check the bit-level unit's encoding against rust.
    """
    x = jnp.asarray(x, jnp.int32)
    a = jnp.abs(x)
    i = _floor_log2_u8(jnp.maximum(a, 1))
    b1 = jnp.where(i >= 1, (a >> jnp.maximum(i - 1, 0)) & 1, 0)
    b0 = jnp.where(i >= 2, (a >> jnp.maximum(i - 2, 0)) & 1, 0)
    e = i + (b1 & b0)
    form = b1 ^ b0
    return jnp.sign(x), jnp.where(a == 0, 0, e), jnp.where(a == 0, 0, form)


def hlog_matmul(x, w):
    """Reference HLog prediction matmul: quantize both operands to HLog
    levels, multiply exactly, accumulate in int32.

    x: (M, K) int8-valued, w: (K, N) int8-valued -> (M, N) int32.

    This is what the bit-level prediction unit computes with shift-adds
    (SJA three-case products + converter accumulation); values are exact
    integers so the float/Pallas implementations must agree bit-for-bit.
    """
    qx = hlog_quantize(x)
    qw = hlog_quantize(w)
    return jnp.matmul(qx, qw, preferred_element_type=jnp.int32)


def requantize_sym8(x):
    """Symmetric per-tensor requantization of an int32 tensor to int8.

    Round-half-away-from-zero (matches rust ``f32::round``); scale chosen
    so max |x| -> 127. Returns (int8-valued int32 array, float scale).
    """
    x = jnp.asarray(x, jnp.float32)
    maxabs = jnp.maximum(jnp.max(jnp.abs(x)), 1e-9)
    s = 127.0 / maxabs
    q = jnp.sign(x) * jnp.floor(jnp.abs(x) * s + 0.5)
    return jnp.clip(q, -127, 127).astype(jnp.int32), s


def predict_attention(x, wq, wk):
    """Full SPLS attention prediction (paper Fig 5a, pre-softmax scores).

    x: (L, D) int8 embeddings; wq, wk: (D, Dh) int8 weights.
    Returns the PAM (L, L) int32: HLog-predicted Q/K, 8-bit requantized,
    HLog-predicted Q @ K^T.
    """
    q_pred = hlog_matmul(x, wq)
    k_pred = hlog_matmul(x, wk)
    q8, _ = requantize_sym8(q_pred)
    k8, _ = requantize_sym8(k_pred)
    return hlog_matmul(q8, jnp.transpose(k8))


# ---------------------------------------------------------------------------
# PoT / APoT comparison quantizers (paper Fig 6/7, Figs 17/18)
# ---------------------------------------------------------------------------


def pot_levels(nbits: int = 8) -> list[int]:
    return [2**m for m in range(nbits)]


def apot_levels(nbits: int = 8, a: int = 2) -> list[int]:
    """Additive powers-of-two with ``a`` = 2 one-hot terms (paper's setting)."""
    base = [2**m for m in range(nbits)]
    lv = set(base)
    for i, hi in enumerate(base):
        for lo in base[:i]:
            if hi + lo < 2**nbits:
                lv.add(hi + lo)
    return sorted(lv)


def _project(x, levels):
    """Project |x| to the nearest level (ties to the higher level)."""
    x = jnp.asarray(x, jnp.int32)
    a = jnp.abs(x)
    lv = jnp.asarray(levels, jnp.int32)
    d = jnp.abs(a[..., None] - lv[None, ...])
    # argmin picks the first minimum; order levels descending so ties go up.
    order = jnp.argsort(-lv)
    dd = d[..., order]
    idx = jnp.argmin(dd, axis=-1)
    mag = lv[order][idx]
    return jnp.where(a == 0, 0, jnp.sign(x) * mag)


def pot_quantize(x, nbits: int = 8):
    return _project(x, pot_levels(nbits))


def apot_quantize(x, nbits: int = 8):
    return _project(x, apot_levels(nbits))


# ---------------------------------------------------------------------------
# Sparse (masked) attention
# ---------------------------------------------------------------------------


def masked_attention(q, k, v, mask, scale=None):
    """Reference SPA-masked attention.

    q, k, v: (L, Dh) f32; mask: (L, L) f32 in {0, 1} (1 = keep).
    Rows of ``mask`` corresponding to similar vectors are expected to be
    copies of their critical row, so the recovered output is exact row
    replication. Returns (L, Dh) f32.
    """
    dh = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    s = jnp.matmul(q, k.T) * scale
    neg = jnp.asarray(-1e30, s.dtype)
    s = jnp.where(mask > 0, s, neg)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p * (mask > 0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.matmul(p / denom, v)


def topk_mask(scores, k_ratio: float):
    """Row-wise top-k mask over a (L, L) score matrix (paper's SPA step).

    Keeps ceil(k_ratio * L) entries per row; ties broken toward lower
    column index (stable argsort), matching the rust implementation.
    """
    l = scores.shape[-1]
    keep = max(1, int(np.ceil(k_ratio * l)))
    idx = jnp.argsort(-scores, axis=-1, stable=True)[..., :keep]
    mask = jnp.zeros_like(scores, dtype=jnp.float32)
    rows = jnp.arange(scores.shape[0])[:, None]
    return mask.at[rows, idx].set(1.0)
