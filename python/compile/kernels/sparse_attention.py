"""L1 Pallas kernel: SPA-masked attention with row recovery.

The formal-phase attention of ESACT computes QK^T only at positions kept
by the sparsified predicted attention (SPA) and only for *critical* rows;
similar rows are recovered by replication (paper §III-C). On the TPU
mapping the SPA mask arrives as a dense {0,1} tile (the rust coordinator
materializes it from the SparsityPlan), and masking happens in-register
after the MXU product — sparsity is *not* exploited for FLOP reduction on
the CPU/interpret path (that is the ASIC simulator's job, `rust/src/sim`);
this kernel exists to make the *numerics* of the sparse model exact and
AOT-exportable.

Row blocks are tiled over the grid; K/V stay VMEM-resident per block
(Dh <= 128 for every model we ship, so a (L, Dh) panel fits comfortably).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _masked_attention_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, scale):
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    mask = m_ref[...]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    neg = jnp.asarray(-1e30, s.dtype)
    s = jnp.where(mask > 0, s, neg)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p * (mask > 0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o_ref[...] = jax.lax.dot_general(
        p / denom, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def masked_attention(q, k, v, mask, *, scale=None, bl: int = 128):
    """SPA-masked attention: q,k,v (L, Dh) f32, mask (L, L) {0,1} -> (L, Dh).

    Matches ``ref.masked_attention`` to float tolerance. Row-blocked grid;
    each block sees the full K/V panel (flash-style K-tiling is a perf
    refinement recorded in EXPERIMENTS.md §Perf, not needed at these L).
    """
    l, dh = q.shape
    if scale is None:
        scale = 1.0 / math.sqrt(dh)
    bl = _block(l, bl)
    grid = (l // bl,)
    kern = lambda qr, kr, vr, mr, orf: _masked_attention_kernel(
        qr, kr, vr, mr, orf, scale=scale
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl, dh), lambda i: (i, 0)),
            pl.BlockSpec((l, dh), lambda i: (0, 0)),
            pl.BlockSpec((l, dh), lambda i: (0, 0)),
            pl.BlockSpec((bl, l), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bl, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, dh), jnp.float32),
        interpret=True,
    )(
        jnp.asarray(q, jnp.float32),
        jnp.asarray(k, jnp.float32),
        jnp.asarray(v, jnp.float32),
        jnp.asarray(mask, jnp.float32),
    )
