"""L1 Pallas kernels: HLog prediction matmul and int8 dense matmul.

This is the TPU re-expression of the paper's bit-level prediction unit
(paper §IV-B). The ASIC computes HLog products with a shift detector +
shift-judgment array (add-only multiplies) + one-hot converter. A TPU has
no bit-level ALU control, so the *same insight* — predict attention in a
cheap log-ish domain before QK generation — maps to:

  * HLog quantization evaluated with integer compare/shift ops in VMEM
    (the shift-detector logic, vectorized on the VPU);
  * the prediction matmul evaluated on the MXU over HLog-level operands.
    Because HLog levels are exact small integers, an f32/int32 MXU matmul
    is bit-identical to the ASIC's shift-add accumulation.

BlockSpec tiling expresses the HBM->VMEM schedule that the ASIC realizes
with its SRAM-banked progressive window pipeline: the (M, K)x(K, N)
product is tiled (bm, bk)x(bk, bn) with the K loop innermost, so each
VMEM-resident tile is reused bn/bm times (see DESIGN.md
§Hardware-Adaptation for the VMEM/MXU estimate).

All kernels run with ``interpret=True``: the CPU PJRT client cannot
execute Mosaic custom-calls; correctness is validated on this path and
real-TPU performance is estimated structurally.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hlog_q(x):
    """Shift-detector HLog quantization on an int32 tile (vector ops only).

    Mirrors ``ref.hlog_quantize``; kept separate because inside a Pallas
    kernel we want the comparison-ladder leading-one detector rather than
    a gather over a level table.
    """
    a = jnp.abs(x)
    sign = jnp.sign(x)
    i = jnp.zeros_like(a)
    for t in (2, 4, 8, 16, 32, 64, 128):
        i = i + (a >= t).astype(a.dtype)
    b1 = jnp.where(i >= 1, (a >> jnp.maximum(i - 1, 0)) & 1, 0)
    b0 = jnp.where(i >= 2, (a >> jnp.maximum(i - 2, 0)) & 1, 0)
    e = i + (b1 & b0)
    form = b1 ^ b0
    mag = jnp.where(form == 1, 3 * (1 << jnp.maximum(e - 1, 0)), 1 << e)
    return jnp.where(a == 0, 0, sign * mag)


def _block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (whole-tile fallback)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _hlog_matmul_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    qx = _hlog_q(x_ref[...].astype(jnp.int32))
    qw = _hlog_q(w_ref[...].astype(jnp.int32))
    o_ref[...] += jax.lax.dot_general(
        qx, qw, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def hlog_matmul(x, w, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """HLog prediction matmul: (M, K) int8-valued x (K, N) int8-valued -> int32.

    Quantizes both operands to HLog levels inside the kernel (fused with
    the matmul tile, as the ASIC fuses SD with SJA) and accumulates exactly.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _hlog_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(x.astype(jnp.int32), w.astype(jnp.int32))


def _int8_matmul_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def int8_matmul(x, w, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """Formal-phase int8 matmul (the paper quantizes all linear weights to
    8 bit): exact int32 accumulation, same tiling as ``hlog_matmul``."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _int8_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(x.astype(jnp.int32), w.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=())
def hlog_quantize(x):
    """Standalone jit-able HLog quantization (VPU path), for L2 use."""
    return _hlog_q(jnp.asarray(x, jnp.int32))
