"""L1 Pallas kernel: row-wise top-k mask over the PAM.

The Top-k unit of ESACT's Functional Module (paper Fig 10/Table II)
selects the k largest predicted scores per attention row to form the
SPA. TPU mapping: rows are tiled over the grid; each (bl, L) row panel
sorts in VMEM (the VPU's bitonic network — `jnp.sort` under
interpret=True) and emits the boolean keep-mask against the k-th
largest value as threshold.

Tie semantics: threshold comparison keeps *all* entries equal to the
k-th value, which can exceed k on exact ties (integer PAMs). The rust
host planner (`spls::topk`) breaks ties toward the lower column index
instead; on continuous scores the two agree exactly, and the tests pin
both behaviours.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _topk_kernel(pam_ref, mask_ref, *, keep):
    rows = pam_ref[...]  # (bl, L)
    sorted_desc = -jnp.sort(-rows, axis=-1)
    thr = sorted_desc[:, keep - 1 : keep]  # k-th largest per row
    mask_ref[...] = (rows >= thr).astype(jnp.float32)


def topk_mask(pam, k_ratio: float, *, bl: int = 128):
    """Row-wise top-k keep mask: (L, L) scores -> (L, L) {0,1} f32.

    ``keep = clamp(ceil(k_ratio · L), 1, L)`` entries per row (more on
    exact ties — see module docstring).
    """
    l, l2 = pam.shape
    assert l == l2, "PAM must be square"
    keep = max(1, min(l, int(-(-k_ratio * l // 1))))
    bl = _block(l, bl)
    kern = functools.partial(_topk_kernel, keep=keep)
    return pl.pallas_call(
        kern,
        grid=(l // bl,),
        in_specs=[pl.BlockSpec((bl, l), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bl, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, l), jnp.float32),
        interpret=True,
    )(jnp.asarray(pam, jnp.float32))
