"""L1 Pallas kernel: fixed-window pairwise L1 distances over SPA rows.

The local-similarity stage of SPLS (paper §III-B) compares rows of the
sparsified predicted attention inside non-overlapping windows of w rows.
On the ASIC this is the 8×26-subtractor bank; on the TPU mapping each
window is one grid step whose (w, L) row panel sits in VMEM and whose
pairwise |a−b| reductions run on the VPU — windows are independent, so
the grid parallelizes exactly like the hardware's per-window units.

The kernel emits the dense (n_windows, w, w) distance tensor plus the
per-row magnitude sums needed for normalization; the greedy
critical/similar assignment stays on the host (it is sequential and
cheap, and the rust coordinator owns it at serve time).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _window_kernel(spa_ref, dist_ref, mass_ref):
    rows = spa_ref[...]  # (w, L)
    a = rows[:, None, :]  # (w, 1, L)
    b = rows[None, :, :]  # (1, w, L)
    # output blocks carry the leading window axis of size 1
    dist_ref[...] = jnp.sum(jnp.abs(a - b), axis=-1)[None]
    mass_ref[...] = jnp.sum(jnp.abs(rows), axis=-1)[None]


def window_l1_distances(spa, *, window: int = 8):
    """Pairwise in-window L1 distances.

    spa: (L, L) float32 (int-valued); L must be divisible by ``window``
    (callers pad the remainder window — mirroring the paper's "remaining
    rows are grouped into an additional window").

    Returns (dist, mass): dist (n_windows, w, w) f32, mass (n_windows, w)
    f32 where ``dist[k, i, j] = Σ|spa[kw+i] − spa[kw+j]|`` and
    ``mass[k, i] = Σ|spa[kw+i]|``.
    """
    l = spa.shape[0]
    assert spa.shape == (l, l), "SPA must be square"
    assert l % window == 0, "pad the remainder window before calling"
    n_windows = l // window
    return pl.pallas_call(
        _window_kernel,
        grid=(n_windows,),
        in_specs=[pl.BlockSpec((window, l), lambda k: (k, 0))],
        out_specs=[
            pl.BlockSpec((1, window, window), lambda k: (k, 0, 0)),
            pl.BlockSpec((1, window), lambda k: (k, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_windows, window, window), jnp.float32),
            jax.ShapeDtypeStruct((n_windows, window), jnp.float32),
        ],
        interpret=True,
    )(jnp.asarray(spa, jnp.float32))


def greedy_assign(dist, mass, threshold: float):
    """Host-side greedy critical/similar assignment from kernel outputs.

    Mirrors rust `spls::similarity::local_similarity`: within each
    window, a row joins the first *critical* row whose normalized L1
    distance ``dist/max(mass_i, mass_j, 1)`` is ≤ threshold, else it
    becomes critical. Returns rep[i] = representative row index.
    """
    import numpy as np

    dist = np.asarray(dist)
    mass = np.asarray(mass)
    n_windows, w, _ = dist.shape
    rep = np.arange(n_windows * w)
    for k in range(n_windows):
        criticals: list[int] = []
        for i in range(w):
            assigned = None
            for c in criticals:
                denom = max(mass[k, i], mass[k, c], 1.0)
                if dist[k, i, c] / denom <= threshold:
                    assigned = c
                    break
            if assigned is None:
                criticals.append(i)
            else:
                rep[k * w + i] = k * w + assigned
    return rep
