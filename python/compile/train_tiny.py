"""Train the tiny transformer on the synthetic local-similarity task and
export weights + a held-out test set for the rust accuracy harness.

Build-time only (invoked from `make artifacts`); nothing here runs at
serve time. Training is plain jax + a hand-written Adam (optax is not in
this image). ~1 minute on CPU for the default 1500 steps.

Usage: python -m compile.train_tiny --out-dir ../artifacts [--steps N]
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as dat
from . import model as M
from .io import write_eswt

SEED = 42
TEST_SEED = 1234
TEST_N = 512


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    bc1, bc2 = 1 - b1**t, 1 - b2**t
    new = {
        k: params[k] - lr * (m[k] / bc1) / (jnp.sqrt(v[k] / bc2) + eps)
        for k in params
    }
    return new, {"m": m, "v": v, "t": t}


def make_loss(cfg):
    fwd = jax.vmap(lambda p, x: M.forward_dense(p, x, cfg), in_axes=(None, 0))

    def loss_fn(p, xs, ys):
        logits = fwd(p, xs)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, ys[:, None], axis=-1).mean()
        return nll, logits

    return fwd, loss_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=1500)
    ap.add_argument("--sparse-steps", type=int, default=1200,
                    help="sparsity-aware fine-tune steps with top-k masked attention")
    ap.add_argument("--k-ratio", type=float, default=0.12)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = M.TinyConfig()
    params = M.init_params(cfg, jax.random.PRNGKey(SEED))
    opt = adam_init(params)
    fwd, loss_fn = make_loss(cfg)
    grad_fn = jax.jit(jax.value_and_grad(lambda p, xs, ys: loss_fn(p, xs, ys)[0]))

    rng = dat.Xoshiro256pp(SEED)
    t0 = time.time()
    for step in range(args.steps):
        xs, ys = dat.gen_batch(rng, args.batch, cfg.seq_len)
        loss, grads = grad_fn(params, jnp.asarray(xs), jnp.asarray(ys))
        params, opt = adam_step(params, grads, opt, lr=args.lr)
        if step % 200 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(loss):.4f} ({time.time()-t0:.0f}s)")

    # --- sparsity-aware fine-tuning (paper §V-B: models are fine-tuned
    # under the sparsity configuration) -------------------------------
    if args.sparse_steps > 0:
        fwd_k = jax.vmap(
            lambda p, x: M.forward_topk(p, x, cfg, args.k_ratio), in_axes=(None, 0)
        )

        def loss_k(p, xs, ys):
            logits = fwd_k(p, xs)
            logp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp.take_along_axis(logp, ys[:, None], axis=-1).mean()

        grad_k = jax.jit(jax.value_and_grad(loss_k))
        for step in range(args.sparse_steps):
            xs, ys = dat.gen_batch(rng, args.batch, cfg.seq_len)
            loss, grads = grad_k(params, jnp.asarray(xs), jnp.asarray(ys))
            params, opt = adam_step(params, grads, opt, lr=args.lr * 0.3)
            if step % 200 == 0 or step == args.sparse_steps - 1:
                print(f"sparse-ft {step:5d} loss {float(loss):.4f} ({time.time()-t0:.0f}s)")

    # Held-out test set (regenerated identically by the rust harness from
    # TEST_SEED; exported anyway so the serve path has concrete requests).
    trng = dat.Xoshiro256pp(TEST_SEED)
    xs, ys = dat.gen_batch(trng, TEST_N, cfg.seq_len)
    acc = float(
        (jnp.argmax(fwd(params, jnp.asarray(xs)), -1) == jnp.asarray(ys)).mean()
    )
    print(f"test accuracy (quant-aware forward): {acc:.4f}")

    # Snap quantized weights (paper: 8-bit weights everywhere) and save.
    qparams = M.quantize_params(params)
    tensors = {k: np.asarray(v, np.float32) for k, v in qparams.items()}
    write_eswt(os.path.join(args.out_dir, "tiny_weights.bin"), tensors)
    write_eswt(
        os.path.join(args.out_dir, "tiny_testset.bin"),
        {
            "tokens": xs.astype(np.int32),
            "labels": ys.astype(np.int32),
            "meta": np.asarray(
                [cfg.vocab, cfg.seq_len, cfg.d_model, cfg.n_heads,
                 cfg.n_layers, cfg.d_ffn, cfg.n_classes], np.int32
            ),
        },
    )
    with open(os.path.join(args.out_dir, "tiny_meta.txt"), "w") as f:
        f.write(
            f"vocab={cfg.vocab}\nseq_len={cfg.seq_len}\nd_model={cfg.d_model}\n"
            f"n_heads={cfg.n_heads}\nn_layers={cfg.n_layers}\nd_ffn={cfg.d_ffn}\n"
            f"n_classes={cfg.n_classes}\ntest_acc={acc:.4f}\nsteps={args.steps}\n"
        )
    print(f"wrote weights + testset to {args.out_dir}")


if __name__ == "__main__":
    main()
