"""Synthetic local-similarity workload generator.

Stands in for the paper's GLUE/SQuAD/WikiText corpora (DESIGN.md
§Substitutions). Sequences are built from *runs* of tokens drawn from the
same semantic cluster — the discrete analogue of the paper's observation
that "neighboring tokens often carry similar semantics" (paper §II-B), so
attention rows inside a local window become similar and SPLS has real
structure to exploit. The label is the majority cluster, which forces the
model to aggregate over the whole sequence (attention is necessary, the
task is not solvable from one position).

The same generator is mirrored in rust (rust/src/workloads/synth.rs) with
the same xoshiro256++ PRNG so both sides can regenerate identical splits
from a seed.
"""

from __future__ import annotations

import numpy as np

N_CLUSTERS = 16
VARIANTS = 4  # tokens per cluster; vocab = N_CLUSTERS * VARIANTS


class Xoshiro256pp:
    """xoshiro256++ PRNG, bit-exact with rust/src/util/rng.rs."""

    def __init__(self, seed: int):
        # splitmix64 seeding, like the rust side.
        s = seed & 0xFFFFFFFFFFFFFFFF
        self.s = []
        for _ in range(4):
            s = (s + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
            z = s
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
            self.s.append(z ^ (z >> 31))

    @staticmethod
    def _rotl(x: int, k: int) -> int:
        return ((x << k) | (x >> (64 - k))) & 0xFFFFFFFFFFFFFFFF

    def next_u64(self) -> int:
        s = self.s
        result = (self._rotl((s[0] + s[3]) & 0xFFFFFFFFFFFFFFFF, 23) + s[0]) & 0xFFFFFFFFFFFFFFFF
        t = (s[1] << 17) & 0xFFFFFFFFFFFFFFFF
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = self._rotl(s[3], 45)
        return result

    def below(self, n: int) -> int:
        """Uniform in [0, n) via modulo (n << 2^64, bias negligible &
        identical on both sides, which is what matters)."""
        return self.next_u64() % n


def gen_example(rng: Xoshiro256pp, seq_len: int) -> tuple[np.ndarray, int]:
    """One (tokens, label) pair: runs of 2..8 same-cluster tokens."""
    toks = np.empty(seq_len, np.int32)
    counts = np.zeros(N_CLUSTERS, np.int64)
    pos = 0
    while pos < seq_len:
        cluster = rng.below(N_CLUSTERS)
        run = 2 + rng.below(7)  # 2..8
        run = min(run, seq_len - pos)
        for _ in range(run):
            toks[pos] = cluster * VARIANTS + rng.below(VARIANTS)
            pos += 1
        counts[cluster] += run
    # Majority cluster; ties -> lowest cluster id (np.argmax convention,
    # mirrored in rust).
    label = int(np.argmax(counts))
    return toks, label


def gen_batch(rng: Xoshiro256pp, n: int, seq_len: int):
    xs = np.empty((n, seq_len), np.int32)
    ys = np.empty((n,), np.int32)
    for i in range(n):
        xs[i], ys[i] = gen_example(rng, seq_len)
    return xs, ys
