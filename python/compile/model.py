"""L2: the JAX model — a tiny transformer classifier used as the accuracy
substrate for the SPLS experiments (see DESIGN.md §Substitutions: stands in
for the paper's fine-tuned BERT/GPT models, which need proprietary-scale
fine-tuning infrastructure).

Architecture (pre-LN encoder, paper Fig 2 computation flow):

  tokens -> embed + pos -> [ MHA(+res) -> FFN(+res) ] x NL -> LN -> mean-pool
         -> linear classifier

Two forward variants share all weights:

  * ``forward_dense``   — the reference dense model;
  * ``forward_masked``  — attention masked by per-(layer, head) SPA masks
    produced by the rust SPLS planner; calls the L1 Pallas kernel
    ``kernels.sparse_attention.masked_attention`` so that the kernel lowers
    into the exported HLO.

All linear weights are 8-bit fake-quantized (symmetric per-tensor) with a
straight-through estimator during training, matching the paper's
"quantize all weights in the Transformer's linear transformations to
8-bit" setup.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.sparse_attention import masked_attention


class TinyConfig(NamedTuple):
    """Model hyperparameters. Defaults are the shipped tiny model."""

    vocab: int = 64
    seq_len: int = 64
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    d_ffn: int = 256
    n_classes: int = 16

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


# Parameter tree: flat dict name -> array. Names are shared verbatim with
# the rust loader (rust/src/model/weights.rs), so keep them stable.
def param_names(cfg: TinyConfig) -> list[str]:
    names = ["embed", "pos"]
    for i in range(cfg.n_layers):
        for w in (
            "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
            "ln1_g", "ln1_b", "w1", "b1", "w2", "b2", "ln2_g", "ln2_b",
        ):
            names.append(f"layer{i}.{w}")
    names += ["lnf_g", "lnf_b", "cls_w", "cls_b"]
    return names


def init_params(cfg: TinyConfig, key) -> dict:
    """Xavier-ish init; biases zero, LN gains one."""

    def dense(key, fan_in, fan_out):
        return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * (
            1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
        )

    keys = iter(jax.random.split(key, 64))
    d, f = cfg.d_model, cfg.d_ffn
    p = {
        "embed": jax.random.normal(next(keys), (cfg.vocab, d)) * 0.02,
        "pos": jax.random.normal(next(keys), (cfg.seq_len, d)) * 0.02,
        "lnf_g": jnp.ones((d,)),
        "lnf_b": jnp.zeros((d,)),
        "cls_w": dense(next(keys), d, cfg.n_classes),
        "cls_b": jnp.zeros((cfg.n_classes,)),
    }
    for i in range(cfg.n_layers):
        p[f"layer{i}.wq"] = dense(next(keys), d, d)
        p[f"layer{i}.wk"] = dense(next(keys), d, d)
        p[f"layer{i}.wv"] = dense(next(keys), d, d)
        p[f"layer{i}.wo"] = dense(next(keys), d, d)
        p[f"layer{i}.w1"] = dense(next(keys), d, f)
        p[f"layer{i}.w2"] = dense(next(keys), f, d)
        for b, shape in (
            ("bq", d), ("bk", d), ("bv", d), ("bo", d), ("b1", f), ("b2", d),
            ("ln1_b", d), ("ln2_b", d),
        ):
            p[f"layer{i}.{b}"] = jnp.zeros((shape,))
        p[f"layer{i}.ln1_g"] = jnp.ones((d,))
        p[f"layer{i}.ln2_g"] = jnp.ones((d,))
    return p


def fake_quant8(w):
    """Symmetric per-tensor int8 fake-quant with STE (train-time QAT)."""
    maxabs = jnp.maximum(jnp.max(jnp.abs(w)), 1e-9)
    s = 127.0 / maxabs
    q = jnp.clip(jnp.sign(w) * jnp.floor(jnp.abs(w) * s + 0.5), -127, 127) / s
    return w + jax.lax.stop_gradient(q - w)


def quantize_params(p: dict) -> dict:
    """Bake the fake-quant into the stored weights (export-time snap).

    Only matmul weights are quantized (paper: linear-transform weights);
    embeddings / LN / biases stay f32.
    """
    out = {}
    for name, w in p.items():
        base = name.split(".")[-1]
        if base in ("wq", "wk", "wv", "wo", "w1", "w2", "cls_w"):
            out[name] = fake_quant8(w)
        else:
            out[name] = w
    return out


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _gelu(x):
    # tanh-approximation GELU, mirrored exactly in rust/src/model/tensor.rs
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, jnp.float32))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _heads(x, cfg: TinyConfig):
    l, d = x.shape
    return x.reshape(l, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)


def _unheads(x, cfg: TinyConfig):
    h, l, dh = x.shape
    return x.transpose(1, 0, 2).reshape(l, h * dh)


def _dense_attention(q, k, v, scale):
    s = jnp.matmul(q, k.T) * scale
    p = jax.nn.softmax(s, axis=-1)
    return jnp.matmul(p, v)


def _block(p, i, x, cfg: TinyConfig, masks=None, quant=True):
    """One transformer block; ``masks`` is (H, L, L) or None for dense."""
    qw = fake_quant8 if quant else (lambda w: w)
    h = _layernorm(x, p[f"layer{i}.ln1_g"], p[f"layer{i}.ln1_b"])
    q = h @ qw(p[f"layer{i}.wq"]) + p[f"layer{i}.bq"]
    k = h @ qw(p[f"layer{i}.wk"]) + p[f"layer{i}.bk"]
    v = h @ qw(p[f"layer{i}.wv"]) + p[f"layer{i}.bv"]
    qh, kh, vh = (_heads(t, cfg) for t in (q, k, v))
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
    outs = []
    for hi in range(cfg.n_heads):
        if masks is None:
            outs.append(_dense_attention(qh[hi], kh[hi], vh[hi], scale))
        else:
            outs.append(masked_attention(qh[hi], kh[hi], vh[hi], masks[hi]))
    att = _unheads(jnp.stack(outs), cfg)
    x = x + att @ qw(p[f"layer{i}.wo"]) + p[f"layer{i}.bo"]
    h2 = _layernorm(x, p[f"layer{i}.ln2_g"], p[f"layer{i}.ln2_b"])
    ff = _gelu(h2 @ qw(p[f"layer{i}.w1"]) + p[f"layer{i}.b1"])
    x = x + ff @ qw(p[f"layer{i}.w2"]) + p[f"layer{i}.b2"]
    return x


def _embed(p, tokens, cfg: TinyConfig):
    return p["embed"][tokens] + p["pos"][: tokens.shape[0]]


def forward_dense(p, tokens, cfg: TinyConfig, quant=True):
    """Dense forward for one sequence: tokens (L,) int32 -> logits (C,)."""
    x = _embed(p, tokens, cfg)
    for i in range(cfg.n_layers):
        x = _block(p, i, x, cfg, masks=None, quant=quant)
    x = _layernorm(x, p["lnf_g"], p["lnf_b"])
    pooled = jnp.mean(x, axis=0)
    qw = fake_quant8 if quant else (lambda w: w)
    return pooled @ qw(p["cls_w"]) + p["cls_b"]


def forward_masked(p, tokens, masks, cfg: TinyConfig, quant=True):
    """SPA-masked forward: masks (NL, H, L, L) in {0,1} -> logits (C,).

    Attention rows of similar vectors carry their critical row's mask, so
    the masked model computes exactly what the ESACT sparse dataflow
    produces after recovery (numerics-level contract with rust/src/model).
    """
    x = _embed(p, tokens, cfg)
    for i in range(cfg.n_layers):
        x = _block(p, i, x, cfg, masks=masks[i], quant=quant)
    x = _layernorm(x, p["lnf_g"], p["lnf_b"])
    pooled = jnp.mean(x, axis=0)
    qw = fake_quant8 if quant else (lambda w: w)
    return pooled @ qw(p["cls_w"]) + p["cls_b"]


def attention_probs(p, tokens, cfg: TinyConfig, quant=True):
    """Per-layer, per-head attention matrices (NL, H, L, L) for the
    local-similarity analysis figures (Fig 3/4)."""
    x = _embed(p, tokens, cfg)
    mats = []
    for i in range(cfg.n_layers):
        qw = fake_quant8 if quant else (lambda w: w)
        h = _layernorm(x, p[f"layer{i}.ln1_g"], p[f"layer{i}.ln1_b"])
        q = h @ qw(p[f"layer{i}.wq"]) + p[f"layer{i}.bq"]
        k = h @ qw(p[f"layer{i}.wk"]) + p[f"layer{i}.bk"]
        qh, kh = _heads(q, cfg), _heads(k, cfg)
        scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
        s = jnp.einsum("hld,hmd->hlm", qh, kh) * scale
        mats.append(jax.nn.softmax(s, axis=-1))
        x = _block(p, i, x, cfg, masks=None, quant=quant)
    return jnp.stack(mats)

def _topk_attention(q, k, v, scale, k_ratio: float):
    """Dense attention with row-wise top-k masking of the scores.

    Used for *sparsity-aware fine-tuning* (paper §V-B: models are
    fine-tuned under each sparsity configuration): the mask is computed
    from the true scores with a stop-gradient threshold, so gradients
    flow through the kept positions only — the model learns to
    concentrate its attention mass into the top-k pattern that the
    ESACT dataflow will actually compute.
    """
    l = q.shape[0]
    keep = max(1, int(np.ceil(k_ratio * l)))
    s = jnp.matmul(q, k.T) * scale
    thr = jax.lax.top_k(s, keep)[0][..., -1:]
    mask = (s >= jax.lax.stop_gradient(thr)).astype(s.dtype)
    neg = jnp.asarray(-1e30, s.dtype)
    sm = jnp.where(mask > 0, s, neg)
    p = jnp.exp(sm - jnp.max(sm, axis=-1, keepdims=True)) * mask
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.matmul(p / denom, v)


def _block_topk(p, i, x, cfg: TinyConfig, k_ratio: float, quant=True):
    """Transformer block with top-k-masked attention (fine-tune path)."""
    qw = fake_quant8 if quant else (lambda w: w)
    h = _layernorm(x, p[f"layer{i}.ln1_g"], p[f"layer{i}.ln1_b"])
    q = h @ qw(p[f"layer{i}.wq"]) + p[f"layer{i}.bq"]
    k = h @ qw(p[f"layer{i}.wk"]) + p[f"layer{i}.bk"]
    v = h @ qw(p[f"layer{i}.wv"]) + p[f"layer{i}.bv"]
    qh, kh, vh = (_heads(t, cfg) for t in (q, k, v))
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
    outs = [
        _topk_attention(qh[hi], kh[hi], vh[hi], scale, k_ratio)
        for hi in range(cfg.n_heads)
    ]
    att = _unheads(jnp.stack(outs), cfg)
    x = x + att @ qw(p[f"layer{i}.wo"]) + p[f"layer{i}.bo"]
    h2 = _layernorm(x, p[f"layer{i}.ln2_g"], p[f"layer{i}.ln2_b"])
    ff = _gelu(h2 @ qw(p[f"layer{i}.w1"]) + p[f"layer{i}.b1"])
    x = x + ff @ qw(p[f"layer{i}.w2"]) + p[f"layer{i}.b2"]
    return x


def forward_topk(p, tokens, cfg: TinyConfig, k_ratio: float, quant=True):
    """Forward with top-k sparse attention (sparsity-aware fine-tuning)."""
    x = _embed(p, tokens, cfg)
    for i in range(cfg.n_layers):
        x = _block_topk(p, i, x, cfg, k_ratio, quant=quant)
    x = _layernorm(x, p["lnf_g"], p["lnf_b"])
    pooled = jnp.mean(x, axis=0)
    qw = fake_quant8 if quant else (lambda w: w)
    return pooled @ qw(p["cls_w"]) + p["cls_b"]
