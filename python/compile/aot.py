"""AOT export: lower the L2 model (with L1 Pallas kernels inside) to HLO
*text* artifacts the rust runtime loads via the xla crate.

HLO text — NOT ``lowered.compile()``/``.serialize()`` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 (the version the published xla 0.1.6 crate binds)
rejects; the text parser reassigns ids and round-trips cleanly
(/opt/xla-example/README.md). Lowered with ``return_tuple=True`` so the
rust side unwraps with ``to_tuple1()``.

Exported artifacts (all shapes static, weights baked as constants — the
deployment model is "weights compiled into the executable", like a real
single-model serving binary):

  tiny_dense_b1.hlo.txt      tokens i32[1,64]                 -> logits f32[1,16]
  tiny_dense_b8.hlo.txt      tokens i32[8,64]                 -> logits f32[8,16]
  tiny_masked_b1.hlo.txt     tokens i32[1,64], masks f32[1,2,4,64,64] -> logits
  tiny_masked_b8.hlo.txt     batch-8 variant
  tiny_attprobs_b1.hlo.txt   tokens i32[1,64] -> attention probs f32[1,2,4,64,64]
  hlog_matmul_64.hlo.txt     x i32[64,64], w i32[64,64]       -> i32[64,64]
  masked_attention_64.hlo.txt q,k,v f32[64,16], mask f32[64,64] -> f32[64,16]

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .io import read_eswt
from .kernels.hlog import hlog_matmul
from .kernels.sparse_attention import masked_attention


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default elides big weight
    # constants as '{...}', which the HLO text parser silently
    # reads back as zeros — the entire model would serve zeros.
    return comp.as_hlo_text(print_large_constants=True)


def dump(fn, example_args, path):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)/1e6:.2f} MB)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    cfg = M.TinyConfig()
    weights_path = os.path.join(out, "tiny_weights.bin")
    if not os.path.exists(weights_path):
        raise SystemExit("run `python -m compile.train_tiny` first (make artifacts does)")
    params = {k: jnp.asarray(v) for k, v in read_eswt(weights_path).items()}

    l, nl, h = cfg.seq_len, cfg.n_layers, cfg.n_heads
    tok = jax.ShapeDtypeStruct((1, l), jnp.int32)
    tok8 = jax.ShapeDtypeStruct((8, l), jnp.int32)
    msk = jax.ShapeDtypeStruct((1, nl, h, l, l), jnp.float32)
    msk8 = jax.ShapeDtypeStruct((8, nl, h, l, l), jnp.float32)

    # Weights already snapped to int8 grid by train_tiny -> quant=False
    # (re-fake-quanting a snapped tensor is a no-op but bloats the HLO).
    dense1 = jax.vmap(lambda t: M.forward_dense(params, t, cfg, quant=False))
    masked = jax.vmap(lambda t, m: M.forward_masked(params, t, m, cfg, quant=False))
    probs = jax.vmap(lambda t: M.attention_probs(params, t, cfg, quant=False))

    dump(lambda t: (dense1(t),), (tok,), f"{out}/tiny_dense_b1.hlo.txt")
    dump(lambda t: (dense1(t),), (tok8,), f"{out}/tiny_dense_b8.hlo.txt")
    dump(lambda t, m: (masked(t, m),), (tok, msk), f"{out}/tiny_masked_b1.hlo.txt")
    dump(lambda t, m: (masked(t, m),), (tok8, msk8), f"{out}/tiny_masked_b8.hlo.txt")
    dump(lambda t: (probs(t),), (tok,), f"{out}/tiny_attprobs_b1.hlo.txt")

    xi = jax.ShapeDtypeStruct((64, 64), jnp.int32)
    dump(lambda x, w: (hlog_matmul(x, w),), (xi, xi), f"{out}/hlog_matmul_64.hlo.txt")

    qf = jax.ShapeDtypeStruct((64, 16), jnp.float32)
    mf = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    dump(
        lambda q, k, v, m: (masked_attention(q, k, v, m),),
        (qf, qf, qf, mf),
        f"{out}/masked_attention_64.hlo.txt",
    )

    # Stamp a manifest so `make artifacts` can skip when inputs unchanged.
    with open(f"{out}/MANIFEST.txt", "w") as f:
        for name in sorted(os.listdir(out)):
            if name.endswith(".hlo.txt") or name.endswith(".bin"):
                f.write(f"{name} {os.path.getsize(os.path.join(out, name))}\n")
    print("AOT export complete")


if __name__ == "__main__":
    main()
