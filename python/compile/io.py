"""ESWT binary tensor container — the weight/dataset interchange format
between the python compile path and the rust runtime.

Layout (little-endian):

  magic   b"ESWT"
  version u32 = 1
  count   u32
  count x records:
    name_len u16, name bytes (utf-8)
    dtype    u8   (0 = f32, 1 = i32, 2 = u16)
    ndim     u8
    dims     ndim x u32
    data     raw, row-major

The rust reader lives in rust/src/util/eswt.rs and round-trips exactly.
"""

from __future__ import annotations

import struct

import numpy as np

_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint16}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.uint16): 2}


def write_eswt(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(b"ESWT")
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            code = _CODES[arr.dtype]
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_eswt(path: str) -> dict[str, np.ndarray]:
    with open(path, "rb") as f:
        assert f.read(4) == b"ESWT", "bad magic"
        version, count = struct.unpack("<II", f.read(8))
        assert version == 1
        out = {}
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            dt = np.dtype(_DTYPES[code])
            n = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(n * dt.itemsize), dtype=dt)
            out[name] = data.reshape(dims)
        return out
